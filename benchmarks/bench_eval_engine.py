"""Eval-engine throughput: legacy host BMA loop vs fused scan engine.

The pre-PR5 evaluation path ran Bayesian model averaging as a traced
Python loop over posterior samples (``bma_predict``) on the full
dataset, then computed accuracy/ECE/NLL/Brier with four separate
host-side calibration calls. ``ScanEvalEngine`` (DESIGN.md §10) replaces
that with one donated ``lax.scan`` over batches, a single vmap over the
stacked bank, and fused streaming metric accumulators.

Three paths are timed on the radar LeNet pool with a realistic bank
(S posterior samples × K node chains):

* ``legacy`` — ``bma_predict`` sample loop + ``core.calibration`` host
  metrics (what ``FedTrainer.evaluate`` did before PR 5);
* ``host`` — the per-batch-dispatch eval oracle (same stats kernel);
* ``scan`` — the fused engine.

Every invocation proves equivalence first (scan == host bitwise, both
within float tolerance of the legacy full-dataset formulas) and asserts
the fused engine beats the legacy loop.

    PYTHONPATH=src python benchmarks/bench_eval_engine.py [--tiny|--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch
from repro.core import calibration as cal
from repro.core.posterior import bma_predict
from repro.data.radar import make_dataset
from repro.eval import HostEvalEngine, ScanEvalEngine
from repro.models import get_model

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results",
                           "eval_engine")


def _bank(model, s: int, k: int):
    """(S, K, ...) stacked synthetic posterior bank."""
    key = jax.random.PRNGKey(0)

    def node_stack(i):
        ps = [model.init(jax.random.fold_in(key, i * k + j))
              for j in range(k)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    banks = [node_stack(i) for i in range(s)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *banks)


def measure(hw, n_eval: int, s: int = 20, k: int = 5, batch: int = 64,
            iters: int = 5) -> Dict:
    cfg = get_arch("lenet-radar").reduced.replace(input_hw=hw)
    model = get_model(cfg)
    stacked = _bank(model, s, k)
    samples = [jax.tree.map(lambda x: x[i], stacked) for i in range(s)]
    ds = make_dataset(n_eval, hw=hw, day=2, seed=7)
    apply = lambda p, b: model.logits(p, b)

    # -- legacy host loop: traced sample loop + host metric formulas ------
    batch_dev = jax.tree.map(jnp.asarray, ds)

    def legacy():
        probs = bma_predict(apply, samples, batch_dev, node_axis=0)
        probs = np.asarray(probs, np.float32)
        return (float(cal.accuracy(probs, ds["y"])),
                float(cal.ece(probs, ds["y"])),
                float(cal.nll(probs, ds["y"])),
                float(cal.brier(probs, ds["y"])))

    host = HostEvalEngine(apply, batch_size=batch)
    scan = ScanEvalEngine(apply, batch_size=batch)

    # -- equivalence proof before any timing ------------------------------
    acc_l, ece_l, nll_l, brier_l = legacy()
    rep_h = host.evaluate(stacked, ds, node_axis=1)
    rep_s = scan.evaluate(stacked, ds, node_axis=1)
    assert rep_s == rep_h._replace(bins=rep_s.bins), \
        "scan engine != host eval oracle"
    for a, b in zip(rep_s.bins, rep_h.bins):
        assert np.array_equal(a, b), "reliability bins mismatch"
    np.testing.assert_allclose(
        [rep_s.accuracy, rep_s.nll, rep_s.brier],
        [acc_l, nll_l, brier_l], atol=2e-5)
    # ECE sums bins in a different order than the full-array formula
    np.testing.assert_allclose(rep_s.ece, ece_l, atol=2e-4)

    def timeit(fn) -> float:
        fn()                                     # warmup / compile
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        return (time.perf_counter() - t0) / iters

    legacy_s = timeit(legacy)
    host_s = timeit(lambda: host.evaluate(stacked, ds, node_axis=1))
    scan_s = timeit(lambda: scan.evaluate(stacked, ds, node_axis=1))
    rec = {
        "hw": f"{hw[0]}x{hw[1]}", "n_eval": n_eval, "bank_s": s, "nodes": k,
        "batch": batch,
        "legacy_examples_per_s": n_eval / legacy_s,
        "host_examples_per_s": n_eval / host_s,
        "scan_examples_per_s": n_eval / scan_s,
        "speedup_vs_legacy": legacy_s / scan_s,
        "speedup_vs_host": host_s / scan_s,
        "equiv_ece_delta": abs(rep_s.ece - ece_l),
    }
    assert rec["scan_examples_per_s"] > rec["legacy_examples_per_s"], (
        f"fused eval engine slower than the legacy host loop: {rec}")
    return rec


def _row(rec: Dict) -> str:
    us = 1e6 / rec["scan_examples_per_s"]
    return (f"eval_engine_{rec['hw']}_n{rec['n_eval']},{us:.1f},"
            f"scan_ex_per_s={rec['scan_examples_per_s']:.0f};"
            f"legacy_ex_per_s={rec['legacy_examples_per_s']:.0f};"
            f"speedup_vs_legacy={rec['speedup_vs_legacy']:.2f};"
            f"speedup_vs_host={rec['speedup_vs_host']:.2f}")


def _save(rec: Dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{rec['hw']}_n{rec['n_eval']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def run(quick: bool = False, tiny: bool = False) -> List[str]:
    if tiny:
        plan = [((16, 16), 192, 8, 3)]
    elif quick:
        plan = [((16, 16), 256, 12, 5), ((32, 16), 256, 12, 5)]
    else:
        plan = [((16, 16), 512, 20, 5), ((32, 16), 512, 20, 5),
                ((32, 16), 2048, 20, 5)]
    rows = []
    for hw, n_eval, s, k in plan:
        rec = measure(hw, n_eval, s=s, k=k,
                      iters=3 if (tiny or quick) else 5)
        _save(rec)
        rows.append(_row(rec))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one small config, ~seconds")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, tiny=args.tiny):
        print(row)


if __name__ == "__main__":
    main()
