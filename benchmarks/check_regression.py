"""CI benchmark-regression gate: compare smoke results against references.

The smoke benches (``bench_round_engine --tiny``, ``bench_wire --tiny``,
``bench_shard_engine --tiny``, ``bench_eval_engine --tiny``,
``bench_transport --tiny``, ``bench_kernels --tiny``,
``bench_fused_compress --tiny``) write JSON records under
``benchmarks/results/<bench>/``. Two kinds of reference
exist, because the two kinds of metric have different portability:

* **Measured bytes** (``*bytes*`` keys) are machine-independent and
  exact: they are hard-gated against the *committed* baselines in
  ``benchmarks/results/baselines/`` — any drift is a real wire-format or
  gossip-plan change and fails, to be re-baselined deliberately with
  ``--update``.
* **Throughput** (``*rounds_per_s`` keys) is not portable across
  machines (dispatch-bound smoke configs swing far beyond 30% between
  runner generations and load). It is hard-gated — fail on a >``--tol``
  (default 30%, env ``BENCH_REGRESSION_TOL``) slowdown — only against a
  *same-runner* reference measured in the same CI job from the PR's
  merge base (``--throughput-ref <dir>``; the tier1 job checks out the
  base, runs the same smokes there, and points the gate at those
  results). Against the committed baselines, throughput deltas are
  reported as warnings only.

A record present in the baselines but missing from the current results
also fails (the smoke did not run). A markdown report is always written
(default ``benchmarks/results/regression_report.md``) — CI uploads it as
a workflow artifact.

``--claims`` is a separate mode gating the *paper's calibration claims*
the way bytes are gated above: it runs the tiny fixed-seed scenario
matrix (``repro.eval.matrix.run_claims_smoke`` — cdbfl vs cffl, clean vs
the day-2/3 safety-critical shift) and hard-fails when a transferable
claim breaks (shift stops degrading accuracy, the Bayesian model stops
retaining predictive entropy under shift, the frequentist model stops
turning overconfident, shifted ECE non-finite or non-reproducible).
It needs ``PYTHONPATH=src`` and writes ``results/claims_report.md``.

    PYTHONPATH=src python benchmarks/check_regression.py
    PYTHONPATH=src python benchmarks/check_regression.py \
        --throughput-ref ../base/benchmarks/results       # PR gate
    PYTHONPATH=src python benchmarks/check_regression.py --update  # rebase
    PYTHONPATH=src python benchmarks/check_regression.py --claims  # claims
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple

HERE = os.path.dirname(os.path.abspath(__file__))
RESULTS = os.path.join(HERE, "results")
BASELINES = os.path.join(RESULTS, "baselines")

# benches gated by default: <bench dir> -> description
BENCHES = {
    "round_engine": "host-loop vs scan-fused engine smoke",
    "wire_tiny": "packed wire-format byte accounting (tiny tree)",
    "shard_engine": "SPMD shard engine smoke (shard_map + ppermute)",
    "eval_engine": "fused BMA eval engine smoke (vs legacy host loop)",
    "transport": "lossy D2D transport: offered/delivered framed bytes",
    "kernels": "Pallas kernel parity bits + fused-update traffic model",
    "fused_compress": "fused encode HBM ledger + bitwise-vs-two-pass bit",
    "serve": "uncertainty-aware serving engine (bitwise + swap leak + req/s)",
    "drift": "drift-recovery protocol (pool purity bits + recovery rounds)",
}

THROUGHPUT_SUFFIX = ("rounds_per_s", "requests_per_s")
# exact-gated machine-independent columns: byte accounting, ARQ
# retransmit counts (both threefry-deterministic integers in f32), and
# the kernels' bitwise-parity bits (1 iff Pallas == reference under jit)
BYTES_TOKENS = ("bytes", "retransmit", "bitwise")
# informational keys never compared (timing-derived or environment-bound)
SKIP_TOKENS = ("speedup", "overhead", "equiv", "_over_", "saving",
               "shard_vs_scan", "delta", "wall")


def _classify(key: str) -> str:
    k = key.lower()
    if any(t in k for t in SKIP_TOKENS):
        return "skip"
    if k.endswith(THROUGHPUT_SUFFIX):
        return "throughput"
    if any(t in k for t in BYTES_TOKENS):
        return "bytes"
    return "skip"


def _load_dir(path: str) -> Dict[str, Dict]:
    out = {}
    for fn in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(fn) as f:
            out[os.path.basename(fn)] = json.load(f)
    return out


def _numeric(rec: Dict, key: str):
    v = rec.get(key)
    return v if isinstance(v, (int, float)) and not isinstance(v, bool) \
        else None


def compare(bench: str, tol: float, throughput_ref: str = None
            ) -> Tuple[List[str], List[str], List[str]]:
    """Returns (report_rows, failures, warnings) for one bench directory."""
    base = _load_dir(os.path.join(BASELINES, bench))
    cur = _load_dir(os.path.join(RESULTS, bench))
    ref = (_load_dir(os.path.join(throughput_ref, bench))
           if throughput_ref else {})
    rows, failures, warnings = [], [], []
    if not base:
        failures.append(f"{bench}: no committed baselines under "
                        f"results/baselines/{bench}/")
        return rows, failures, warnings
    for name, brec in base.items():
        crec = cur.get(name)
        if crec is None:
            failures.append(f"{bench}/{name}: baseline has no current "
                            f"result — did the smoke bench run?")
            continue
        for key, bval in brec.items():
            kind = _classify(key)
            if kind == "skip" or _numeric(brec, key) is None:
                continue
            cval = _numeric(crec, key)
            if cval is None:
                failures.append(f"{bench}/{name}:{key}: missing in current")
                continue
            if kind == "bytes":
                ok = float(cval) == float(bval)
                rows.append(f"| {bench}/{name} | {key} | {bval:g} "
                            f"| {cval:g} | — | exact "
                            f"| {'ok' if ok else 'FAIL (bytes mismatch)'} |")
                if not ok:
                    failures.append(
                        f"{bench}/{name}:{key}: measured {cval:g} != "
                        f"baseline {bval:g} (byte accounting is exact; "
                        f"re-baseline with --update if intended)")
                continue
            # throughput: hard gate vs same-runner reference, warn vs
            # the committed (cross-machine) baseline
            rval = _numeric(ref.get(name, {}), key)
            if rval is not None and rval > 0:
                ratio = cval / rval
                ok = cval >= rval * (1.0 - tol)
                rows.append(f"| {bench}/{name} | {key} | {rval:.1f} "
                            f"| {cval:.1f} | {ratio:.2f}× | same-runner "
                            f"| {'ok' if ok else f'FAIL (>{tol:.0%} slower)'} |")
                if not ok:
                    failures.append(
                        f"{bench}/{name}:{key}: {cval:.1f} vs same-runner "
                        f"merge-base {rval:.1f} "
                        f"({1 - ratio:.1%} slowdown > {tol:.0%})")
            else:
                ratio = cval / bval if bval else float("inf")
                note = "ok" if cval >= bval * (1.0 - tol) else "WARN (slower)"
                rows.append(f"| {bench}/{name} | {key} | {bval:.1f} "
                            f"| {cval:.1f} | {ratio:.2f}× | cross-machine "
                            f"| {note} |")
                if note != "ok":
                    warnings.append(
                        f"{bench}/{name}:{key}: {cval:.1f} vs committed "
                        f"baseline {bval:.1f} — informational only "
                        f"(different machine); the PR gate compares "
                        f"same-runner merge-base results")
    return rows, failures, warnings


def run_claims(out_path: str) -> None:
    """The calibration-claims gate (CI job ``claims``): run the tiny
    fixed-seed scenario matrix and hard-fail on any broken claim."""
    import importlib.util
    if importlib.util.find_spec("repro") is None:   # pragma: no cover
        print("claims gate needs PYTHONPATH=src (repro not importable)",
              file=sys.stderr)
        sys.exit(2)
    from repro.eval.matrix import (matrix_markdown, run_claims_smoke,
                                   run_drift_claims)

    out = run_claims_smoke()
    drift = run_drift_claims()
    report = [
        "# Calibration claims report",
        "",
        "Gate: the paper's transferable shift-calibration claims on the "
        "fixed-seed tiny scenario matrix (`repro.eval.matrix.CLAIMS_SPEC`). "
        "Hard failures: non-finite or non-reproducible shifted ECE, shift "
        "no longer degrading accuracy, the Bayesian model losing its "
        "predictive-entropy margin under shift, the frequentist model "
        "losing its overconfidence onset. The raw reduced-scale ECE "
        "ordering is reported as a warning (DESIGN.md §10).",
        "",
        matrix_markdown(out["cells"]),
        "",
        "## Claim values",
        "",
    ]
    report += [f"* {k}: {v}" for k, v in out["claims"].items()]
    report += [
        "",
        "## Drift recovery (DESIGN.md §15)",
        "",
        "Gate: after a step drift "
        f"(`{drift['claims']['drift_scenario']}` at severity "
        f"{drift['claims']['drift_severity']:g}, onset round "
        f"{drift['claims']['drift_onset']}), cdbfl with bank aging must "
        "bring probe ECE back within the pre-drift band inside "
        "`DRIFT_RECOVERY_MAX_ROUNDS` rounds of onset; the uncompressed "
        "dsgld baseline is reported for comparison, not gated.",
        "",
        "| algorithm | pre-drift ECE | excursion round | recovery round "
        "| rounds to recovery |",
        "|---|---|---|---|---|",
    ]
    for alg, curve in drift["curves"].items():
        report.append(
            f"| {alg} | {curve['pre_ece']:.4f} "
            f"| {curve['excursion_round']} | {curve['recovery_round']} "
            f"| {curve['rounds_to_recovery']} |")
    report += ["", "### Probe curves", ""]
    for alg, curve in drift["curves"].items():
        report += [f"**{alg}**", "",
                   "| round | severity | accuracy | ECE |",
                   "|---|---|---|---|"]
        report += [f"| {int(p['round'])} | {p['severity']:g} "
                   f"| {p['accuracy']:.4f} | {p['ece']:.4f} |"
                   for p in curve["probes"]]
        report.append("")
    report += [f"* {k}: {v}" for k, v in drift["claims"].items()]
    out["failures"] = list(out["failures"]) + list(drift["failures"])
    if out["failures"]:
        report += ["", "## Failures", ""] + \
            [f"* {f}" for f in out["failures"]]
    if out["warnings"]:
        report += ["", "## Warnings (non-fatal)", ""] + \
            [f"* {w}" for w in out["warnings"]]
    if not out["failures"]:
        report += ["", "All gated claims hold."]
    text = "\n".join(report) + "\n"
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)
    print(text)
    if out["failures"]:
        print(f"CLAIMS GATE FAILED ({len(out['failures'])} issue(s)); "
              f"report: {out_path}", file=sys.stderr)
        sys.exit(1)
    print(f"claims gate passed; report: {out_path}")


def update_baselines(benches) -> None:
    for bench in benches:
        src = os.path.join(RESULTS, bench)
        dst = os.path.join(BASELINES, bench)
        if not os.path.isdir(src):
            print(f"[skip] {bench}: no current results to promote")
            continue
        os.makedirs(dst, exist_ok=True)
        for fn in glob.glob(os.path.join(src, "*.json")):
            shutil.copy2(fn, dst)
        print(f"[update] {bench}: baselines <- results/{bench}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=",".join(BENCHES),
                    help="comma-separated bench dirs to gate")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_REGRESSION_TOL",
                                                 0.30)),
                    help="max tolerated rounds/sec slowdown (fraction)")
    ap.add_argument("--throughput-ref", default=None,
                    help="results dir measured on THIS runner from the "
                         "merge base; enables the hard throughput gate")
    ap.add_argument("--out", default=os.path.join(RESULTS,
                                                  "regression_report.md"))
    ap.add_argument("--update", action="store_true",
                    help="promote current results to baselines and exit")
    ap.add_argument("--claims", action="store_true",
                    help="run the tiny fixed-seed scenario matrix and "
                         "gate the paper's calibration claims")
    ap.add_argument("--claims-out",
                    default=os.path.join(RESULTS, "claims_report.md"))
    args = ap.parse_args()
    benches = [b.strip() for b in args.bench.split(",") if b.strip()]

    if args.claims:
        run_claims(args.claims_out)
        return

    if args.update:
        update_baselines(benches)
        return

    all_rows: List[str] = []
    all_failures: List[str] = []
    all_warnings: List[str] = []
    for bench in benches:
        rows, failures, warnings = compare(bench, args.tol,
                                           args.throughput_ref)
        all_rows.extend(rows)
        all_failures.extend(failures)
        all_warnings.extend(warnings)

    report = [
        "# Benchmark regression report",
        "",
        f"Gate: any measured-bytes mismatch vs committed baselines fails; "
        f">{args.tol:.0%} rounds/sec slowdown vs a same-runner merge-base "
        f"reference fails"
        + ("" if args.throughput_ref else
           " (no --throughput-ref given: throughput is compared to the "
           "committed cross-machine baselines as warnings only)") + ".",
        "",
        "| record | metric | reference | current | ratio | basis | verdict |",
        "|---|---|---|---|---|---|---|",
        *all_rows,
        "",
    ]
    if all_failures:
        report += ["## Failures", ""] + [f"* {f}" for f in all_failures] + [""]
    if all_warnings:
        report += ["## Warnings (non-fatal)", ""] + \
            [f"* {w}" for w in all_warnings] + [""]
    if not all_failures:
        report += ["All gated metrics within tolerance."]
    text = "\n".join(report) + "\n"
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    print(text)
    if all_failures:
        print(f"REGRESSION GATE FAILED ({len(all_failures)} issue(s)); "
              f"report: {args.out}", file=sys.stderr)
        sys.exit(1)
    print(f"regression gate passed; report: {args.out}")


if __name__ == "__main__":
    main()
