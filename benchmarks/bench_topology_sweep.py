"""Beyond-paper sweep: device-graph topology × link reliability.

The paper evaluates one graph (full, K=10). An IIoT deployment sees sparse,
irregular, failure-prone D2D graphs; this sweep (EXPERIMENTS §Topology
sweep) reports, per graph family:

* spectral gap / |λ₂| of the Metropolis Ω (the CHOCO-bound quantity);
* wire bytes per node per round for the schedule mixer — O(deg·p), i.e.
  one compressed payload per matching — vs the dense all-gather's O(K·p);
* schedule-vs-dense max abs error (must be ≤1e-5 in float32);
* accuracy / ECE of CD-BFL trained over the graph, including per-round
  link dropout (reduced scale per DESIGN.md §7).
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from benchmarks.common import radar_world, run_method
from repro.config import TopologyConfig, get_arch
from repro.core.compression import Compressor
from repro.core.gossip import dense_mix, plan_mixer, schedule_mix
from repro.core.topology import (build_schedule, build_topology,
                                 dense_wire_bytes, spectral_gap)
from repro.models import get_model

# K for the structural sweep (square for grid/torus); paper uses K=10
K_STRUCT = 16

SWEEP = [
    ("full", TopologyConfig(graph="full")),
    ("ring", TopologyConfig(graph="ring")),
    ("torus", TopologyConfig(graph="torus")),
    ("grid", TopologyConfig(graph="grid")),
    ("star", TopologyConfig(graph="star")),
    ("k_regular_4", TopologyConfig(graph="k_regular", degree=4)),
    ("erdos_renyi_p30", TopologyConfig(graph="erdos_renyi", edge_prob=0.3,
                                       seed=3)),
    ("geometric_r45", TopologyConfig(graph="geometric", radius=0.45, seed=7)),
]


def _payload_bytes() -> float:
    """Compressed Δθ payload for the paper's 2.7M-param LeNet @1% top-k."""
    cfg = get_arch("lenet-radar").config
    specs = jax.eval_shape(lambda: get_model(cfg).init(jax.random.PRNGKey(0)))
    return Compressor(name="topk", ratio=0.01).wire_bytes(specs)


def _schedule_error(omega: np.ndarray) -> float:
    sched = build_schedule(omega)
    k = omega.shape[0]
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (k, 33))}
    a = np.asarray(schedule_mix(sched, x)["w"])
    b = np.asarray(dense_mix(omega, x)["w"])
    return float(np.abs(a - b).max())


def run(quick: bool = False, tiny: bool = False) -> List[str]:
    rows = []
    payload = _payload_bytes()

    # -- structural sweep: spectral gap + wire bytes per graph family -------
    for name, tc in SWEEP:
        topo = build_topology(tc, K_STRUCT)
        # same decision make_mixer executes: schedule mixer (O(deg·p)) for
        # bounded-degree graphs, all-gather for dense-ish ones (deg ≥ K-1);
        # plan_mixer skips the decomposition on the dense path, so build it
        # here anyway — the matching count is part of this diagnostic
        mode, sched = plan_mixer(topo.omega, tc)
        sched = sched or build_schedule(topo.omega)
        dense_b = dense_wire_bytes(K_STRUCT, payload)
        wire = (sched.wire_bytes(payload) if mode.startswith("schedule")
                else dense_b)
        err = _schedule_error(topo.omega)
        rows.append(
            f"topo_{name},0,"
            f"K={K_STRUCT};deg={topo.max_degree};edges={topo.num_edges};"
            f"gap={topo.spectral_gap:.4f};lambda2={topo.lambda2:.4f};"
            f"matchings={sched.num_perms};mixer={mode};"
            f"wire_bytes={wire:.4g};wire_dense={dense_b:.4g};"
            f"saving_pct={100 * (1 - wire / dense_b):.1f};"
            f"sched_vs_dense_err={err:.2e}")

    if tiny:
        # CI smoke: the structural sweep alone (spectral gaps, wire
        # accounting, schedule-vs-dense error) — no training runs
        return rows

    # -- dropout sweep: expected-Ω spectral gap under per-link failures -----
    # E[Ω_t] = (1-p)·Ω + p·I in the Laplacian masking scheme, so the
    # expected consensus rate degrades as gap·(1-p); report it per graph.
    for name, tc in (SWEEP if not quick else SWEEP[:3]):
        topo = build_topology(tc, K_STRUCT)
        for p_drop in (0.1, 0.3, 0.5):
            om_eff = (1 - p_drop) * topo.omega + p_drop * np.eye(K_STRUCT)
            rows.append(
                f"dropout_{name}_p{int(100 * p_drop)},0,"
                f"gap={topo.spectral_gap:.4f};"
                f"gap_effective={spectral_gap(om_eff):.4f}")

    # -- training sweep: accuracy/calibration over graphs × dropout --------
    rounds = 40 if quick else 120
    train_sweep = [
        ("full", TopologyConfig(graph="full"), 0.0),
        ("ring", TopologyConfig(graph="ring"), 0.0),
    ]
    if not quick:
        train_sweep += [
            ("k_regular_2", TopologyConfig(graph="k_regular", degree=2), 0.0),
            ("geometric_r60", TopologyConfig(graph="geometric", radius=0.6,
                                             seed=7), 0.0),
            ("ring_drop20", TopologyConfig(graph="ring",
                                           link_failure_prob=0.2), 0.2),
            ("ring_pair1", TopologyConfig(graph="ring", gossip_pairs=1), 0.0),
        ]
    _, model, shards, test_d1, _ = radar_world()
    for name, tc, p_drop in train_sweep:
        tr, res = run_method(model, shards, "cdbfl", rounds=rounds,
                             compressor="topk", eval_batch=test_d1,
                             topology=tc.graph, topology_cfg=tc)
        rows.append(
            f"train_{name},0,"
            f"gap={tr.topology.spectral_gap:.4f};"
            f"acc={res.accuracy:.4f};ece={res.ece:.4f};nll={res.nll:.4f};"
            f"bytes_per_round={res.bytes_sent_per_round:.4g};"
            f"rounds={rounds};link_failure={p_drop}")
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: structural sweep only, no training")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, tiny=args.tiny):
        print(row)


if __name__ == "__main__":
    main()
