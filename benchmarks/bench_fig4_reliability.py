"""Paper Fig. 4: reliability diagrams under distribution shift.

Train on day-1, evaluate on the safety-critical day-2/3 scenario cells.
Claim: CD-BFL and DSGLD retain predictive uncertainty (confidence tracks
accuracy); CF-FL turns overconfident (confidence >> accuracy) — the
paper's central safety argument.

Since PR 5 this is a thin wrapper over the scenario-matrix runner
(``repro.eval.matrix``): training + fused-engine evaluation + claim
checks all live there, and the same cells are hard-gated in CI by
``benchmarks/check_regression.py --claims``.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import PER_NODE_SHIFT, ROUNDS
from repro.core import calibration as cal
from repro.eval.matrix import (CLAIMS_CFFL_GAP_RISE_MIN, CLAIMS_ECE_MARGIN,
                               MatrixSpec, run_matrix)

SHIFT = ("day23_critical", 1.0)


def run(quick: bool = False) -> List[str]:
    rows = []
    rounds = 60 if quick else ROUNDS
    spec = MatrixSpec(
        algorithms=("dsgld", "cdbfl", "cffl"), pipelines=("",),
        cells=(("clean", 0.0), SHIFT),
        rounds=rounds, per_node=PER_NODE_SHIFT,
    )
    cells = run_matrix(spec, log=None)
    shift = {c.algorithm: c for c in cells if c.scenario == SHIFT[0]}
    clean = {c.algorithm: c for c in cells if c.scenario == "clean"}

    for algo in spec.algorithms:
        c = shift[algo]
        r = c.report
        rows.append(f"fig4_{algo}_shift,{c.train_wall_s*1e6/rounds:.0f},"
                    f"acc={r.accuracy:.4f};ece={r.ece:.4f};"
                    f"overconf_gap={r.overconf_gap:+.4f};"
                    f"entropy={r.entropy:.4f}")

    # the ordering claims as derived rows: the raw ECE ordering (fragile
    # at reduced scale, reported) and the overconfidence-onset claim
    # (gated in CI — the frequentist model is the one the shift breaks)
    ece_ok = (shift["cdbfl"].report.ece
              <= shift["cffl"].report.ece + CLAIMS_ECE_MARGIN)
    rows.append(f"fig4_claim_cdbfl_better_calibrated,0,"
                f"cdbfl_ece={shift['cdbfl'].report.ece:.4f};"
                f"cffl_ece={shift['cffl'].report.ece:.4f};holds={ece_ok}")
    gap_rise = (shift["cffl"].report.overconf_gap
                - clean["cffl"].report.overconf_gap)
    rows.append(f"fig4_claim_cffl_overconfidence_onset,0,"
                f"cffl_gap_rise={gap_rise:+.4f};"
                f"cdbfl_shift_gap={shift['cdbfl'].report.overconf_gap:+.4f};"
                f"holds={gap_rise >= CLAIMS_CFFL_GAP_RISE_MIN}")
    for algo in spec.algorithms:
        print(cal.render_reliability(shift[algo].report.bins,
                                     f"{algo} (day-2/3, labels 1-6)"))
    return rows
