"""Paper Fig. 4: reliability diagrams under distribution shift.

Train on day-1, evaluate on the safety-critical subset (labels 1-6) of
days 2-3. Claim: CD-BFL and DSGLD stay calibrated (confidence tracks
accuracy); CF-FL is overconfident (confidence >> accuracy) — the paper's
central safety argument.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import PER_NODE_SHIFT, ROUNDS, radar_world, run_method
from repro.core import calibration as cal


def run(quick: bool = False) -> List[str]:
    rows = []
    cfg, model, shards, _, test_shift = radar_world(per_node=PER_NODE_SHIFT)
    rounds = 60 if quick else ROUNDS

    diagrams = {}
    for algo in ("dsgld", "cdbfl", "cffl"):
        _, res = run_method(model, shards, algo, local_steps=8,
                            rounds=rounds, eval_batch=test_shift)
        bins = cal.reliability_bins(jnp.asarray(res.probs),
                                    jnp.asarray(res.labels), 10)
        # mean confidence-accuracy gap over occupied bins (signed:
        # positive = overconfident)
        occ = np.asarray(bins.bin_counts) > 0
        gap = float(np.mean((np.asarray(bins.bin_confidence)
                             - np.asarray(bins.bin_accuracy))[occ]))
        diagrams[algo] = (res, gap, bins)
        rows.append(f"fig4_{algo}_shift,{res.wall_s*1e6/rounds:.0f},"
                    f"acc={res.accuracy:.4f};ece={res.ece:.4f};"
                    f"overconf_gap={gap:+.4f}")

    # the ordering claim itself, as a derived row
    ece_ok = diagrams["cdbfl"][0].ece <= diagrams["cffl"][0].ece + 0.02
    rows.append(f"fig4_claim_cdbfl_better_calibrated,0,"
                f"cdbfl_ece={diagrams['cdbfl'][0].ece:.4f};"
                f"cffl_ece={diagrams['cffl'][0].ece:.4f};holds={ece_ok}")
    for algo, (res, gap, bins) in diagrams.items():
        print(cal.render_reliability(bins, f"{algo} (days 2-3, labels 1-6)"))
    return rows
