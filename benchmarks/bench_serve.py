"""Serving-engine benchmark: open-loop load on the BMA serving plane.

Drives ``repro.serve`` the way a deployment would: a Poisson arrival
stream admitted into the fixed-shape slot table while earlier requests
are still in flight (continuous batching), measuring request throughput
and tail latency. Before any timing, every invocation proves the
engine's contracts:

* ``serve_vs_eval_bitwise`` — BMA probabilities from the serving path
  are bitwise-equal to a :class:`ScanEvalEngine` pass over the same
  bank (gated exactly: 1.0 or the serving plane lies about uncertainty);
* ``swap_cache_leak_bytes`` — device bytes after N posterior hot swaps
  minus steady state (gated exactly: 0.0; the pre-PR9 serve demo's
  per-sample cache list re-allocated on every bank change);
* zero recompiles after warmup (asserted inline — continuous batching
  must never change a traced shape).

``*_requests_per_s`` rows are throughput-gated like ``rounds_per_s``
(same-runner merge-base reference hard gate, cross-machine warn);
``p50_ms``/``p99_ms``/``abstain_rate`` are informational.

    PYTHONPATH=src python benchmarks/bench_serve.py [--tiny|--quick]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, get_arch
from repro.data.radar import make_dataset
from repro.eval import ScanEvalEngine
from repro.models import get_model
from repro.serve import (ClassifyEngine, DecodeEngine, ServeRequest,
                         live_device_bytes)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "serve")


def _bank(model, s: int, k: int = 0):
    """Synthetic stacked posterior: (S, K, ...) or (S, ...) when k=0."""
    key = jax.random.PRNGKey(0)

    def node_stack(i):
        if k == 0:
            return model.init(jax.random.fold_in(key, i))
        ps = [model.init(jax.random.fold_in(key, i * k + j))
              for j in range(k)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[node_stack(i) for i in range(s)])


def _open_loop(make_engine, make_request, n_requests: int, lam: float):
    """Poisson arrivals against a live engine; returns (resps, dt, eng)."""
    eng = make_engine()
    eng.run([make_request(0)])                        # warmup (compiles)
    c0 = eng.compile_count()
    rng = np.random.default_rng(0)
    resps, submitted = [], 0
    t0 = time.perf_counter()
    while submitted < n_requests or eng.pending():
        k = int(rng.poisson(lam))
        for _ in range(min(k, n_requests - submitted)):
            eng.submit(make_request(1 + submitted))
            submitted += 1
        if eng.pending():
            resps.extend(eng.step())
    dt = max(time.perf_counter() - t0, 1e-9)
    assert eng.compile_count() == c0, (
        f"recompiled under open-loop load: {eng.compile_count()} vs {c0}")
    assert len(resps) == n_requests
    return resps, dt, eng


def _swap_leak(eng, stacked, make_request) -> int:
    """Device-byte delta across posterior hot swaps, after steady state."""
    def swap_and_serve(i):
        eng.install_bank(
            jax.tree.map(lambda x: x + 0.01 * (i + 1), stacked))
        eng.run([make_request(900 + i)])

    swap_and_serve(0)                                 # reach steady state
    gc.collect()
    b0 = live_device_bytes()
    for i in range(1, 5):
        swap_and_serve(i)
    gc.collect()
    return live_device_bytes() - b0


def measure_classify(hw, n_requests: int, s: int, k: int, slots: int,
                     lam: float) -> Dict:
    cfg = get_arch("lenet-radar").reduced.replace(input_hw=hw)
    model = get_model(cfg)
    stacked = _bank(model, s, k)
    ds = make_dataset(max(n_requests, slots * 2), hw=hw, day=2, seed=7)
    apply = lambda p, b: model.logits(p, b)
    scfg = ServeConfig(slots=slots, entropy_threshold=float(np.log(9)))

    def mk_engine():
        return ClassifyEngine(apply, scfg, input_shape=ds["x"].shape[1:],
                              stacked=stacked, node_axis=1)

    def mk_request(i):
        return ServeRequest(x=ds["x"][i % len(ds["y"])])

    # -- contract proofs before timing ------------------------------------
    eng = mk_engine()
    m = slots * 2
    probe = eng.run([ServeRequest(x=ds["x"][i]) for i in range(m)])
    sub = {f: v[:m] for f, v in ds.items()}
    _, eval_probs = ScanEvalEngine(apply, batch_size=slots).evaluate(
        stacked, sub, node_axis=1, return_probs=True)
    bitwise = float(np.array_equal(np.stack([r.probs for r in probe]),
                                   eval_probs))
    leak = _swap_leak(eng, stacked, mk_request)

    resps, dt, eng = _open_loop(mk_engine, mk_request, n_requests, lam)
    lat = np.asarray([r.latency_s for r in resps]) * 1e3
    return {
        "mode": "classify", "hw": f"{hw[0]}x{hw[1]}", "bank_s": s,
        "nodes": k, "slots": slots, "n_requests": n_requests,
        "classify_requests_per_s": n_requests / dt,
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "abstain_rate": eng.stats()["abstain_rate"],
        "serve_vs_eval_bitwise": bitwise,
        "swap_cache_leak_bytes": float(leak),
    }


def measure_decode(n_requests: int, m: int, slots: int, new_tokens: int,
                   lam: float) -> Dict:
    cfg = get_arch("smollm-135m").reduced
    model = get_model(cfg)
    stacked = _bank(model, m)
    scfg = ServeConfig(slots=slots, max_len=4 * new_tokens,
                       max_new_tokens=new_tokens)

    def mk_engine():
        return DecodeEngine(model, scfg, stacked=stacked)

    def mk_request(i):
        return ServeRequest(prompt_token=1 + i % (cfg.vocab_size - 1),
                            seed=i)

    leak = _swap_leak(mk_engine(), stacked, mk_request)
    resps, dt, eng = _open_loop(mk_engine, mk_request, n_requests, lam)
    lat = np.asarray([r.latency_s for r in resps]) * 1e3
    toks = sum(len(r.tokens) for r in resps)
    return {
        "mode": "decode", "arch": cfg.name, "bank_s": m, "slots": slots,
        "n_requests": n_requests, "new_tokens": new_tokens,
        "decode_requests_per_s": n_requests / dt,
        "tok_per_s": toks / dt,
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "swap_cache_leak_bytes": float(leak),
    }


def _row(rec: Dict) -> str:
    key = f"{rec['mode']}_requests_per_s"
    us = 1e6 / rec[key]
    name = (f"serve_{rec['mode']}_s{rec['bank_s']}_slots{rec['slots']}"
            f"_n{rec['n_requests']}")
    extra = (f"bitwise={rec['serve_vs_eval_bitwise']:.0f};"
             if "serve_vs_eval_bitwise" in rec else "")
    return (f"{name},{us:.1f},"
            f"req_per_s={rec[key]:.1f};p50_ms={rec['p50_ms']:.2f};"
            f"p99_ms={rec['p99_ms']:.2f};{extra}"
            f"leak_B={rec['swap_cache_leak_bytes']:.0f}")


def _save(rec: Dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = (f"{rec['mode']}_s{rec['bank_s']}_slots{rec['slots']}"
            f"_n{rec['n_requests']}.json")
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def run(quick: bool = False, tiny: bool = False) -> List[str]:
    if tiny:
        plan_c = [((16, 16), 48, 4, 2, 8, 4.0)]
        plan_d = [(16, 3, 4, 4, 2.0)]
    elif quick:
        plan_c = [((16, 16), 128, 8, 3, 8, 6.0)]
        plan_d = [(32, 4, 4, 8, 2.0)]
    else:
        plan_c = [((16, 16), 256, 12, 5, 8, 6.0),
                  ((32, 16), 256, 12, 5, 16, 8.0)]
        plan_d = [(64, 4, 8, 16, 2.0)]
    rows = []
    for hw, n, s, k, slots, lam in plan_c:
        rec = measure_classify(hw, n, s, k, slots, lam)
        assert rec["serve_vs_eval_bitwise"] == 1.0, rec
        assert rec["swap_cache_leak_bytes"] == 0.0, rec
        _save(rec)
        rows.append(_row(rec))
    for n, m, slots, new_tokens, lam in plan_d:
        rec = measure_decode(n, m, slots, new_tokens, lam)
        assert rec["swap_cache_leak_bytes"] == 0.0, rec
        _save(rec)
        rows.append(_row(rec))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one small config per mode, ~seconds")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, tiny=args.tiny):
        print(row)


if __name__ == "__main__":
    main()
