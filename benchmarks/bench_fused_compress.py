"""Fused compress-in-update: HBM-traffic ledger + roofline (DESIGN.md §13).

The tentpole's acceptance numbers, from the static per-encode HBM ledger
(``repro.core.compression.encode_hbm_bytes`` — machine-independent python
ints counted from the lowered program's shapes, so every byte column here
is exact-gateable in check_regression):

* **reduction** — two-pass traffic / fused traffic per ``encode_pair``.
  The two-pass path materializes the dense residual and a padded copy of
  it (~5p reads+writes and up); the fused kernels read theta and v once
  and write wire-sized buffers. Must be >= 2x at the smollm-135M config.
* **bound ratio** — fused traffic / the ``2p reads + wire writes`` lower
  bound (the residual *must* be a function of theta and v, and the wire
  payload *must* be written). Must be <= 1.5x.
* **roofline** — t_mem vs t_comp of the fused encode at TPU peak numbers
  (``benchmarks.roofline``): the encode is bandwidth-bound (t_mem
  dominates), so saved bytes are saved wall-clock.

``--tiny`` additionally runs a live bitwise check (fused payload vs the
two-pass oracle, under jit) on a small tree and writes the gate records
under ``results/fused_compress/``.

    PYTHONPATH=src python -m benchmarks.bench_fused_compress [--tiny|--quick]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.roofline import HBM_BW, PEAK_FLOPS
from repro.core.compression import (FusedCodec, encode_hbm_bytes,
                                    parse_pipeline)
from repro.kernels.pack import BISECT_ITERS

KEY = jax.random.PRNGKey(0)
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results",
                           "fused_compress")

PIPELINES = ["block_topk", "block_topk|qsgd"]

# gate tree: fixed ragged shapes (aligned head, head+tail, tail-only)
TINY_SHAPES = {"emb": (1000, 64), "w1": (4097,), "w2": (33, 7)}
TINY_RATIO, TINY_BS = 0.05, 128


def _codecs(spec: str, ratio: float, block_size: int):
    base = parse_pipeline(spec, ratio=ratio, block_size=block_size)
    return (FusedCodec.wrap(base, fused=True),
            FusedCodec.wrap(base, fused=False))


def _spec_tree(shapes) -> dict:
    return {k: jax.ShapeDtypeStruct(s, jnp.float32)
            for k, s in shapes.items()}


def _wire_bytes(codec, theta) -> int:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(codec.encode, theta, key).measured_bytes()


def _encode_flops(n: int, ratio: float, block_size: int) -> float:
    """Static FLOP model of the fused encode, per element:

    1 (delta) + ~4/iter bisection threshold search (BISECT_ITERS fixed
    iterations over every element) + ~k one-hot prefix-rank compaction
    ops + ~6 QSGD grid ops on the k survivors (O(wire), negligible).
    Deliberately generous to compute — if t_mem still dominates, the
    bandwidth-bound classification is robust.
    """
    k = max(1, int(np.ceil(ratio * block_size)))
    return float(n) * (1 + 4 * BISECT_ITERS + k + 6 * k / block_size)


def _roofline(spec: str, theta, v, ratio: float, block_size: int) -> dict:
    fused, oracle = _codecs(spec, ratio, block_size)
    f = encode_hbm_bytes(fused, theta, v)
    o = encode_hbm_bytes(oracle, theta, v)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(theta))
    t_mem = f["hbm_bytes"] / HBM_BW
    t_comp = _encode_flops(n, ratio, block_size) / PEAK_FLOPS
    return {
        "pipeline": spec, "n_params": n,
        "fused_hbm_bytes": f["hbm_bytes"],
        "fused_read_bytes": f["read_bytes"],
        "fused_write_bytes": f["write_bytes"],
        "two_pass_hbm_bytes": o["hbm_bytes"],
        "lower_bound_bytes": f["lower_bound_bytes"],
        "wire_bytes": _wire_bytes(fused, theta),
        "reduction_x": o["hbm_bytes"] / f["hbm_bytes"],
        "bound_ratio": f["hbm_bytes"] / f["lower_bound_bytes"],
        "t_mem_s": t_mem, "t_comp_s": t_comp,
        "dominant": "memory" if t_mem > t_comp else "compute",
    }


def _bitwise_match(spec: str) -> int:
    """Live check: fused payload == two-pass oracle payload, under jit."""
    fused, oracle = _codecs(spec, TINY_RATIO, TINY_BS)
    ks = jax.random.split(KEY, 2 * len(TINY_SHAPES))
    theta = {k: jax.random.normal(ks[2 * i], s)
             for i, (k, s) in enumerate(TINY_SHAPES.items())}
    v = {k: 0.1 * jax.random.normal(ks[2 * i + 1], s)
         for i, (k, s) in enumerate(TINY_SHAPES.items())}
    pf = jax.jit(lambda t, vv, k: fused.encode_pair(t, vv, k))(theta, v, KEY)
    po = jax.jit(lambda t, vv, k: oracle.encode_pair(t, vv, k))(theta, v,
                                                                KEY)
    ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(po)))
    return int(ok)


def run(quick: bool = False, tiny: bool = False) -> List[str]:
    rows = []
    if tiny:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        theta = _spec_tree(TINY_SHAPES)
        for spec in PIPELINES:
            rec = _roofline(spec, theta, theta, TINY_RATIO, TINY_BS)
            rec["bitwise_match"] = _bitwise_match(spec)
            fn = spec.replace("|", "_")
            with open(os.path.join(RESULTS_DIR, f"{fn}.json"), "w") as f:
                json.dump(rec, f, indent=1)
            rows.append(
                f"fused_compress_{fn},0,"
                f"fused={rec['fused_hbm_bytes']};"
                f"two_pass={rec['two_pass_hbm_bytes']};"
                f"reduction={rec['reduction_x']:.2f}x;"
                f"bound_ratio={rec['bound_ratio']:.3f};"
                f"bitwise={rec['bitwise_match']}")
        return rows

    # paper-scale config: smollm-135M parameter tree, shapes only (the
    # ledger is static, so no 540MB materialization on the CI box)
    from repro.config import get_arch
    from repro.models import get_model
    cfg = get_arch("smollm-135m").reduced if quick \
        else get_arch("smollm-135m").config
    model = get_model(cfg)
    theta = jax.eval_shape(model.init,
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
    for spec in PIPELINES:
        rec = _roofline(spec, theta, theta, ratio=0.01, block_size=1024)
        label = spec.replace("|", "_")
        rows.append(
            f"fused_compress_135m_{label},0,"
            f"n={rec['n_params']};fused={rec['fused_hbm_bytes']};"
            f"two_pass={rec['two_pass_hbm_bytes']};"
            f"lower_bound={rec['lower_bound_bytes']};"
            f"reduction={rec['reduction_x']:.2f}x;"
            f"bound_ratio={rec['bound_ratio']:.3f};"
            f"t_mem={rec['t_mem_s']:.3e};t_comp={rec['t_comp_s']:.3e};"
            f"dominant={rec['dominant']}")
        # the tentpole's acceptance criteria, asserted where measured
        assert rec["reduction_x"] >= 2.0, rec
        assert rec["bound_ratio"] <= 1.5, rec
        assert rec["dominant"] == "memory", rec
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small fixed tree, gate records + live "
                         "bitwise check, ~seconds")
    ap.add_argument("--quick", action="store_true",
                    help="reduced smollm config instead of the full 135M")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, tiny=args.tiny):
        print(row)


if __name__ == "__main__":
    main()
