"""Paper Table (implied, §V headline): communication overhead per method.

Bytes on the wire per device per round, for the paper's p=2.7M LeNet and
for the assigned production archs — showing the 99% claim and how it scales
to the multi-pod deployment where CD-BFL compresses inter-pod traffic.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, list_archs
from repro.core.compression import Compressor
from repro.models import get_model


def _tree_specs(cfg):
    model = get_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def run(quick: bool = False) -> List[str]:
    rows = []
    compressors = {
        "dense_fp32": Compressor(name="identity"),
        "topk_1pct": Compressor(name="topk", ratio=0.01),
        "block_topk_1pct": Compressor(name="block_topk", ratio=0.01),
        "qsgd_4bit": Compressor(name="qsgd", qsgd_levels=16),
        "sign_1bit": Compressor(name="sign"),
    }

    # paper model at full scale (2.7M params, real 256x63 maps)
    archs = ["lenet-radar"] if quick else [
        "lenet-radar", "smollm-135m", "recurrentgemma-9b", "grok-1-314b"]
    for arch in archs:
        cfg = get_arch(arch).config
        specs = _tree_specs(cfg)
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(specs))
        dense = compressors["dense_fp32"].wire_bytes(specs)
        for cname, comp in compressors.items():
            b = comp.wire_bytes(specs)
            rows.append(
                f"comm_{arch}_{cname},0,"
                f"params={n};bytes_per_node_round={b:.4g};"
                f"saving_pct={100*(1-b/dense):.2f}")
    return rows
