"""§Roofline: derive the three roofline terms from the dry-run records.

    compute    = HLO_FLOPs_per_device / 197e12           (bf16 peak/chip)
    memory     = HLO_bytes_per_device / 819e9            (HBM BW/chip)
    collective = collective_bytes_per_device / 50e9      (ICI per link)

plus MODEL_FLOPS = 6·N_active·D tokens (training; 2·N_active for a forward
pass, 2·N_active per generated token for decode) and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs × devices).

Methodology notes (also printed with the table):
* HLO numbers come from repro.launch.hlo_cost (post-opt HLO walk with
  while-body trip multiplication) — not from XLA's raw cost_analysis, which
  counts loop bodies once.
* The CPU backend lowers ragged_dot (MoE grouped GEMM) as a DENSE
  all-experts dot, so HLO_FLOPs for MoE archs overcount by ~E/top_k on the
  expert FFN part; a real TPU executes the grouped form. moe_corrected
  subtracts the known artifact.
* collective bytes assume ring algorithms and one ICI link; multi-link
  meshes divide this term accordingly.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.config import INPUT_SHAPES, get_arch

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


# --------------------------------------------------------------------------
# Analytic parameter/FLOP model
# --------------------------------------------------------------------------

def param_counts(cfg) -> Dict[str, float]:
    """(total, active) parameter counts from the config."""
    d, ff, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads

    def attn_params():
        if cfg.kv_lora_rank:
            lq, lkv, rp = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
            p = d * lkv + lkv * h * hd * 2 + d * rp + h * hd * d
            p += (d * lq + lq * h * (hd + rp)) if lq else d * h * (hd + rp)
            return p
        return d * h * hd + 2 * d * kv * hd + h * hd * d

    def mlp_params(width=None):
        w = width or ff
        return 3 * d * w

    total = active = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        per = d * 3 * d + d * 2 * cfg.num_heads + d * d * 2   # mlstm approx
        total += L * per
        active += L * per
        return {"total": total, "active": active}
    if cfg.family == "hybrid":
        pat = (cfg.block_pattern * ((L // len(cfg.block_pattern)) + 1))[:L]
        dr = cfg.rglru_dim or d
        rec = d * 2 * dr + 4 * dr + 2 * dr * dr + dr * d
        for m in pat:
            total += (rec if m == "rec" else attn_params()) + mlp_params()
        active = total
        return {"total": total, "active": active}
    enc = cfg.encoder_layers if cfg.family == "audio" else 0
    for _ in range(L + enc):
        a = attn_params()
        if cfg.moe.num_experts:
            e_all = cfg.moe.num_experts * mlp_params()
            e_act = cfg.moe.top_k * mlp_params()
            shared = cfg.moe.num_shared_experts * mlp_params()
            router = d * cfg.moe.num_experts
            total += a + e_all + shared + router
            active += a + e_act + shared + router
        else:
            total += a + mlp_params()
            active += a + mlp_params()
    if cfg.family == "audio":   # cross-attention
        total += L * attn_params()
        active += L * attn_params()
    return {"total": total, "active": active}


def model_flops(cfg, shape, step: str) -> float:
    """Global useful FLOPs for one step (6ND train / 2ND forward rules)."""
    pc = param_counts(cfg)
    n_act = pc["active"]
    if step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per request; attention reads the cache (memory-bound,
    # the flops term is the projections)
    return 2.0 * n_act * shape.global_batch


def moe_flops_artifact(cfg, shape, step: str) -> float:
    """CPU-backend ragged_dot artifact: dense-all-experts minus grouped."""
    if not cfg.moe.num_experts:
        return 0.0
    d, ff = cfg.d_model, cfg.d_ff
    tokens = shape.global_batch * (shape.seq_len if step != "serve" else 1)
    per_tok_dense = cfg.num_layers * cfg.moe.num_experts * 3 * d * ff * 2
    per_tok_grouped = cfg.num_layers * cfg.moe.top_k * 3 * d * ff * 2
    # fed: L=4 local fwd+bwd passes over ~the same global token budget
    mult = {"train": 3.0, "prefill": 1.0, "serve": 1.0, "fed": 12.0}[step]
    return (per_tok_dense - per_tok_grouped) * tokens * mult


# --------------------------------------------------------------------------
# Table builder
# --------------------------------------------------------------------------

def load_records(results_dir: str = RESULTS_DIR) -> List[Dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: Dict) -> Optional[Dict]:
    if "skipped" in rec or "error" in rec:
        return None
    arch = get_arch(rec["arch"])
    cfg = arch.config
    if rec.get("variant", "").startswith("sliding_window"):
        cfg = cfg.replace(sliding_window=4096)
    shape = INPUT_SHAPES[rec["shape"]]
    step = rec["step"]
    ndev = rec["num_devices"]

    hlo_flops = rec["flops_per_device"]
    # gshard dispatch does not use ragged_dot; its dense one-hot einsums are
    # the real TPU cost of that formulation — no artifact to subtract.
    if "moe_gshard" in rec.get("variant", ""):
        artifact = 0.0
    else:
        artifact = moe_flops_artifact(cfg, shape, step) / ndev
    hlo_flops_corr = max(hlo_flops - artifact, hlo_flops * 0.02)

    t_comp = hlo_flops_corr / PEAK_FLOPS
    # memory term: fused (TPU-fusion) model; the raw per-op bound is kept as
    # t_memory_upper_s. Old records without the fused field fall back to raw.
    mem_bytes = rec.get("hbm_bytes_fused_per_device",
                        rec["hbm_bytes_per_device"])
    t_mem = mem_bytes / HBM_BW
    t_mem_upper = rec["hbm_bytes_per_device"] / HBM_BW
    t_coll = rec["collective_total_per_device"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, step)
    ratio = mf / max(hlo_flops_corr * ndev, 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "step": step, "variant": rec.get("variant", "base"),
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_upper_s": t_mem_upper, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_dev": hlo_flops,
        "hlo_flops_dev_corrected": hlo_flops_corr,
        "useful_ratio": ratio,
        "state_gib_dev": rec["state_bytes_per_device"] / 2 ** 30,
    }


def run(quick: bool = False) -> List[str]:
    rows = []
    for rec in load_records():
        r = roofline_row(rec)
        if r is None:
            continue
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        rows.append(
            f"{name},0,"
            f"compute_s={r['t_compute_s']:.3e};mem_s={r['t_memory_s']:.3e};"
            f"coll_s={r['t_collective_s']:.3e};dominant={r['dominant']};"
            f"useful={r['useful_ratio']:.3f};state_gib={r['state_gib_dev']:.2f}")
    if not rows:
        rows.append("roofline_pending,0,run `python -m repro.launch.dryrun"
                    " --all --both-meshes --out benchmarks/results/dryrun`")
    return rows


def markdown_table(results_dir: str = RESULTS_DIR,
                   mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | step | compute s | memory s | collective s | "
        "dominant | useful | GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records(results_dir):
        r = roofline_row(rec)
        if r is None or r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['state_gib_dev']:.2f} |")
    return "\n".join(lines)
