"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

CAVEAT printed with results: interpret=True executes the kernel body via
the CPU interpreter, so *wall time here is NOT TPU performance* — the CSV
exists to track relative regressions and to validate call overhead. TPU
performance is assessed structurally in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def run(quick: bool = False) -> List[str]:
    rows = []
    n = 2 ** 18 if quick else 2 ** 21   # 2M params ~ the paper's LeNet
    x = jax.random.normal(KEY, (n,))

    # block top-k
    t_pallas = timeit(lambda: ops.block_topk(x, ratio=0.01), iters=3)
    x2d, _ = ops._pad_to_2d(x, 1024, 8)
    jref = jax.jit(lambda a: ref.block_topk_ref(a, k=11))
    t_ref = timeit(lambda: jref(x2d), iters=3)
    rows.append(f"kernel_block_topk_pallas_interp,{t_pallas:.0f},n={n}")
    rows.append(f"kernel_block_topk_jnp_ref,{t_ref:.0f},n={n}")

    # fused update
    ks = jax.random.split(KEY, 4)
    th, vb, v, xi = [jax.random.normal(k, (n,)) for k in ks]
    t_pallas = timeit(lambda: ops.fused_update(th, vb, v, xi, zeta=0.03,
                                               noise_scale=0.014), iters=3)
    jref2 = jax.jit(lambda a, b, c, d: ref.fused_update_ref(a, b, c, d, 0.03, 0.014))
    t_ref = timeit(lambda: jref2(th, vb, v, xi), iters=3)
    rows.append(f"kernel_fused_update_pallas_interp,{t_pallas:.0f},n={n}")
    rows.append(f"kernel_fused_update_jnp_ref,{t_ref:.0f},n={n}")

    # qsgd
    t_pallas = timeit(lambda: ops.qsgd(x, KEY, levels=16), iters=3)
    rows.append(f"kernel_qsgd_pallas_interp,{t_pallas:.0f},n={n}")

    # derived: HBM traffic model for the fused kernel on TPU
    # unfused: 3 elementwise ops = (2+2+2) reads + 3 writes = 9n floats
    # fused: 4 reads + 1 write = 5n floats -> 1.8x traffic cut
    rows.append("kernel_fused_update_traffic_model,0,"
                "unfused_floats=9n;fused_floats=5n;cut=1.80x")
    return rows
