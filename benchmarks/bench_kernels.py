"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

CAVEAT printed with results: interpret=True executes the kernel body via
the CPU interpreter, so *wall time here is NOT TPU performance* — the CSV
exists to track relative regressions and to validate call overhead. TPU
performance is assessed structurally in EXPERIMENTS.md §Roofline.

``--tiny`` writes the machine-independent gate records under
``results/kernels/`` for check_regression: live bitwise-parity bits
(pack/unpack round-trip, QSGD kernel vs the codec stage under jit, fused
delta-pack vs pack-after-materialize) and the fused-update HBM traffic
model — exact integers, safe to hard-gate.

    PYTHONPATH=src python -m benchmarks.bench_kernels [--tiny|--quick]
"""
from __future__ import annotations

import argparse
import functools
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "kernels")


def _parity_record(n: int) -> dict:
    """Exact parity bits between the Pallas kernels and their references
    (all checks under jit — the kernels' bitwise contract; see
    tests/test_kernels.py on why eager differs in the last ulp)."""
    x = jax.random.normal(KEY, (n,))
    # pack -> unpack round-trips to the dense masked leaf
    vals, idx = ops.block_topk_pack(x, ratio=0.01, block_size=1024)
    back = ops.block_topk_unpack(vals, idx, n, (n,), block_size=1024)
    dense = ops.block_topk(x, ratio=0.01, block_size=1024)
    pack_rt = int(np.array_equal(np.asarray(back), np.asarray(dense)))
    # qsgd kernel vs the jitted codec stage
    from repro.core.compression import _qsgd_leaf
    want = jax.jit(functools.partial(_qsgd_leaf, levels=16))(x, KEY)
    got = ops.qsgd(x, KEY, levels=16)
    qsgd_match = int(np.array_equal(np.asarray(got), np.asarray(want)))
    # fused delta-pack vs pack of the materialized residual
    v = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    fv, fi = ops.fused_delta_pack(x, v, ratio=0.01, block_size=1024)
    mv, mi = jax.jit(lambda t, vv: ops.block_topk_pack(
        t - vv, ratio=0.01, block_size=1024))(x, v)
    fused_match = int(np.array_equal(np.asarray(fv), np.asarray(mv))
                      and np.array_equal(np.asarray(fi), np.asarray(mi)))
    return {"n": n, "bitwise_pack_roundtrip": pack_rt,
            "bitwise_qsgd_vs_codec": qsgd_match,
            "bitwise_fused_delta_pack": fused_match}


def run(quick: bool = False, tiny: bool = False) -> List[str]:
    if tiny:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        rec = _parity_record(2 ** 14)
        # fused Eq. 9 update HBM model (f32 bytes of the 9n-vs-5n floats)
        traffic = {"unfused_bytes_per_elem": 36, "fused_bytes_per_elem": 20}
        with open(os.path.join(RESULTS_DIR, "parity.json"), "w") as f:
            json.dump(rec, f, indent=1)
        with open(os.path.join(RESULTS_DIR, "fused_update_traffic.json"),
                  "w") as f:
            json.dump(traffic, f, indent=1)
        return [
            f"kernel_parity,0,pack_rt={rec['bitwise_pack_roundtrip']};"
            f"qsgd={rec['bitwise_qsgd_vs_codec']};"
            f"fused_delta_pack={rec['bitwise_fused_delta_pack']};"
            f"n={rec['n']}",
            "kernel_fused_update_traffic_model,0,"
            "unfused_floats=9n;fused_floats=5n;cut=1.80x",
        ]
    rows = []
    n = 2 ** 18 if quick else 2 ** 21   # 2M params ~ the paper's LeNet
    x = jax.random.normal(KEY, (n,))

    # block top-k
    t_pallas = timeit(lambda: ops.block_topk(x, ratio=0.01), iters=3)
    x2d, _ = ops._pad_to_2d(x, 1024, 8)
    jref = jax.jit(lambda a: ref.block_topk_ref(a, k=11))
    t_ref = timeit(lambda: jref(x2d), iters=3)
    rows.append(f"kernel_block_topk_pallas_interp,{t_pallas:.0f},n={n}")
    rows.append(f"kernel_block_topk_jnp_ref,{t_ref:.0f},n={n}")

    # fused update
    ks = jax.random.split(KEY, 4)
    th, vb, v, xi = [jax.random.normal(k, (n,)) for k in ks]
    t_pallas = timeit(lambda: ops.fused_update(th, vb, v, xi, zeta=0.03,
                                               noise_scale=0.014), iters=3)
    jref2 = jax.jit(lambda a, b, c, d: ref.fused_update_ref(a, b, c, d, 0.03, 0.014))
    t_ref = timeit(lambda: jref2(th, vb, v, xi), iters=3)
    rows.append(f"kernel_fused_update_pallas_interp,{t_pallas:.0f},n={n}")
    rows.append(f"kernel_fused_update_jnp_ref,{t_ref:.0f},n={n}")

    # qsgd
    t_pallas = timeit(lambda: ops.qsgd(x, KEY, levels=16), iters=3)
    rows.append(f"kernel_qsgd_pallas_interp,{t_pallas:.0f},n={n}")

    # fused compress-in-update (DESIGN.md §13)
    v = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    t_fused = timeit(lambda: ops.fused_delta_pack(x, v, ratio=0.01,
                                                  block_size=1024), iters=3)
    rows.append(f"kernel_fused_delta_pack_interp,{t_fused:.0f},n={n}")
    vals, _ = ops.fused_delta_pack(x, v, ratio=0.01, block_size=1024)
    t_q = timeit(lambda: ops.qsgd_quantize_carrier(vals, KEY, levels=16),
                 iters=3)
    rows.append(f"kernel_qsgd_carrier_interp,{t_q:.0f},"
                f"carrier={vals.shape[0]}x{vals.shape[1]}")

    # derived: HBM traffic model for the fused kernel on TPU
    # unfused: 3 elementwise ops = (2+2+2) reads + 3 writes = 9n floats
    # fused: 4 reads + 1 write = 5n floats -> 1.8x traffic cut
    rows.append("kernel_fused_update_traffic_model,0,"
                "unfused_floats=9n;fused_floats=5n;cut=1.80x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: parity gate records, ~seconds")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, tiny=args.tiny):
        print(row)


if __name__ == "__main__":
    main()
