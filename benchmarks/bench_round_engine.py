"""Round-engine throughput: seed-style host loop vs scan-fused engine.

Measures rounds/sec of the two execution engines (DESIGN.md §8) across
model sizes and chunk lengths, and reports the *host-overhead fraction*
``1 - scan_s/host_s`` — the share of the per-round wall time the seed
harness spent on host-side work (numpy minibatch sampling + H2D, one jit
dispatch per round, blocking metric syncs, D2H posterior-bank pulls) that
the scan engine eliminates.

Model sizes span the two regimes:

* ``linear32`` — a CD-BFL round over a 32-dim linear model: dispatch-bound
  (round compute ≪ host overhead). This is where scan fusion shines.
* ``lenet16`` / ``lenet32x16`` — the paper's radar LeNet at CI scale:
  compute-bound on CPU (conv fwd+bwd dominates), so the engines converge.

Every invocation also *proves* engine equivalence: HostRoundEngine and
ScanRoundEngine consume identical PRNG streams, and the final params are
asserted allclose before any timing is reported.

    PYTHONPATH=src python benchmarks/bench_round_engine.py [--tiny|--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig, get_arch
from repro.core import (SampleBank, build_topology, init_fed_state,
                        make_compressor, make_round_fn, resolve_topology)
from repro.core.posterior import DeviceSampleBank
from repro.data.partition import (DeviceShards, minibatch_stack,
                                  partition_iid)
from repro.models import get_model
from repro.train.engine import make_engine

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results",
                           "round_engine")


# --------------------------------------------------------------------------
# Model-size worlds
# --------------------------------------------------------------------------

def _linear_world(k: int, dim: int = 32, per_node: int = 50):
    rng = np.random.default_rng(0)
    shards = [{"x": rng.normal(size=(per_node, dim)).astype(np.float32),
               "y": rng.normal(size=(per_node,)).astype(np.float32)}
              for _ in range(k)]

    def loss(params, batch, key):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2), ()

    params0 = {"w": jnp.zeros((dim,)), "b": jnp.zeros(())}
    return loss, params0, shards


def _lenet_world(k: int, hw, per_node: int = 50):
    cfg = get_arch("lenet-radar").reduced.replace(input_hw=hw)
    model = get_model(cfg)
    from repro.data.radar import make_dataset
    ds = make_dataset(k * per_node, hw=hw, day=1, seed=0)
    shards = partition_iid(ds, k)
    params0 = model.init(jax.random.PRNGKey(0))
    return model.loss, params0, shards


SIZES = {
    "linear32": lambda k: _linear_world(k),
    "lenet16": lambda k: _lenet_world(k, (16, 16)),
    "lenet32x16": lambda k: _lenet_world(k, (32, 16)),
}


# --------------------------------------------------------------------------
# Measurement
# --------------------------------------------------------------------------

def measure(size: str, chunk: int, rounds: int, k: int = 5, local_steps: int = 2,
            minibatch: int = 4, verify_rounds: int = 8) -> Dict:
    """Time host loop vs scan engine; assert engine equivalence first."""
    loss_fn, params0, shards = SIZES[size](k)
    fed = FedConfig(
        num_nodes=k, local_steps=local_steps, eta=1e-3, zeta=0.3, burn_in=0,
        compressor="topk", compress_ratio=0.1, topology="ring",
        algorithm="cdbfl",
    )
    topo = build_topology(resolve_topology(fed), k)
    comp = make_compressor(fed)
    round_fn = make_round_fn("cdbfl", loss_fn, fed, topo.omega, comp,
                             data_scale=50.0)
    dshards = DeviceShards.from_shards(shards)
    bank_cfg = DeviceSampleBank(burn_in=0, capacity=40, thin=1)
    key = jax.random.PRNGKey(0)

    # -- equivalence proof: same streams, allclose final params ------------
    def run_engine(name, n):
        eng = make_engine(name, round_fn, dshards, local_steps, minibatch,
                          bank=bank_cfg, chunk=chunk)
        state = init_fed_state(params0, fed, key=key)
        bs = (bank_cfg.init(state.params) if name == "scan"
              else eng.make_bank())
        return eng.run(state, jax.random.PRNGKey(1), bs, n)

    s_h, _, _, loss_h, _ = run_engine("host", verify_rounds)
    s_s, _, _, loss_s, _ = run_engine("scan", verify_rounds)
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree.leaves(s_h.params),
                             jax.tree.leaves(s_s.params))]
    equiv_diff = max(diffs)
    assert equiv_diff < 1e-4, f"engine mismatch on {size}: {equiv_diff}"
    assert np.allclose(loss_h, loss_s, atol=1e-5), "loss history mismatch"

    # -- seed-style host loop (numpy sampling + H2D + per-round sync) -----
    rfj = jax.jit(round_fn)
    state = init_fed_state(params0, fed, key=key)
    keyh = jax.random.PRNGKey(1)
    bank = SampleBank(burn_in=0, max_samples=40, thin=1)
    rng = np.random.default_rng(0)

    def host_round(state, keyh, t):
        batches = minibatch_stack(shards, local_steps, minibatch, rng)
        batches = jax.tree.map(jnp.asarray, batches)
        keyh, kround = jax.random.split(keyh)
        state, m = rfj(state, batches, kround)
        _ = float(jnp.mean(m.loss))
        _ = float(m.consensus_error)
        bank.maybe_add(t, state.params)
        return state, keyh

    for t in range(3):                                   # warmup / compile
        state, keyh = host_round(state, keyh, t)
    t0 = time.perf_counter()
    for t in range(rounds):
        state, keyh = host_round(state, keyh, t + 3)
    jax.block_until_ready(state.params)
    host_s = time.perf_counter() - t0

    # -- scan engine, chunked ---------------------------------------------
    eng = make_engine("scan", round_fn, dshards, local_steps, minibatch,
                      bank=bank_cfg, chunk=chunk)
    state = init_fed_state(params0, fed, key=key)
    bs = bank_cfg.init(state.params)
    state, k2, bs, _, _ = eng.run(state, jax.random.PRNGKey(1), bs,
                                  chunk)                 # warmup / compile
    t0 = time.perf_counter()
    state, k2, bs, _, _ = eng.run(state, k2, bs, rounds, t0=chunk)
    jax.block_until_ready(state.params)
    scan_s = time.perf_counter() - t0

    return {
        "size": size, "chunk": chunk, "rounds": rounds, "nodes": k,
        "local_steps": local_steps, "minibatch": minibatch,
        "host_rounds_per_s": rounds / host_s,
        "scan_rounds_per_s": rounds / scan_s,
        "speedup": host_s / scan_s,
        "host_overhead_frac": 1.0 - scan_s / host_s,
        "equiv_max_abs_diff": equiv_diff,
    }


def _row(rec: Dict) -> str:
    us = 1e6 / rec["scan_rounds_per_s"]
    return (f"round_engine_{rec['size']}_c{rec['chunk']},{us:.0f},"
            f"scan_rps={rec['scan_rounds_per_s']:.1f};"
            f"host_rps={rec['host_rounds_per_s']:.1f};"
            f"speedup={rec['speedup']:.2f};"
            f"host_overhead_frac={rec['host_overhead_frac']:.3f}")


def _save(rec: Dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR,
                        f"{rec['size']}_c{rec['chunk']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def run(quick: bool = False, tiny: bool = False) -> List[str]:
    """Benchmark-suite entry point (CSV rows for benchmarks.run)."""
    if tiny:
        plan = [("linear32", 16, 32)]
    elif quick:
        plan = [("linear32", 64, 64), ("lenet16", 64, 64)]
    else:
        plan = [(size, chunk, 64 if size != "linear32" else 256)
                for size in SIZES
                for chunk in (8, 64)]
    rows = []
    for size, chunk, rounds in plan:
        rec = measure(size, chunk, rounds)
        _save(rec)
        rows.append(_row(rec))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one dispatch-bound config, ~seconds")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, tiny=args.tiny):
        print(row)


if __name__ == "__main__":
    main()
