"""Shard-engine throughput & byte accounting on forced CPU meshes.

Measures rounds/sec of :class:`ShardRoundEngine` (shard_map + explicit
``lax.ppermute`` gossip) against the scan and host engines on the same
workload, across 2/4/8-shard ``--xla_force_host_platform_device_count``
CPU meshes, and reports the wire split the SPMD path makes measurable:

* ``wire_B``  — compressed payload bytes/node/round (what the protocol
  ships; identical across engines),
* ``cross_B`` — bytes/node/round the Ω-mixing physically moved *between*
  shards (ppermute rows × row bytes — the traffic CD-BFL compresses on a
  real multi-device deployment),
* ``intra_B`` — partner rows resolved by shard-local gathers.

Every invocation first proves trajectory equivalence: the shard engine's
final params must match the scan engine's to ≤1e-6 (and the host loop to
≤1e-5) under the shared PRNG streams (per-node streams key off global node
ids), else no timing is reported; whether the match was *bitwise* is
recorded per config (it is exact whenever XLA emits the same per-node
kernels for the local and global batch shapes — always on the test
worlds, shape-dependent for the 32-dim world at small shards/node).
On this container's CPU the collectives are memcpys between logical
devices, so shard rounds/sec is expected to trail scan — the benchmark
pins the overhead and the byte model, not a speedup.

    PYTHONPATH=src python benchmarks/bench_shard_engine.py [--tiny|--quick]
"""
if __name__ == "__main__":           # entry point only: never on import
    import sys
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.xla_flags import force_host_device_count
    force_host_device_count(8)

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig
from repro.core import (ShardContext, build_topology, init_fed_state,
                        make_compressor, make_round_fn, resolve_topology)
from repro.core.posterior import DeviceSampleBank
from repro.data.partition import DeviceShards
from repro.train.engine import make_engine

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results",
                           "shard_engine")


def _linear_world(k: int, dim: int = 32, per_node: int = 50):
    rng = np.random.default_rng(0)
    shards = [{"x": rng.normal(size=(per_node, dim)).astype(np.float32),
               "y": rng.normal(size=(per_node,)).astype(np.float32)}
              for _ in range(k)]

    def loss(params, batch, key):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2), ()

    params0 = {"w": jnp.zeros((dim,)), "b": jnp.zeros(())}
    return loss, params0, shards


def _lenet_world(k: int, per_node: int = 50):
    from repro.config import get_arch
    from repro.data.radar import make_dataset
    from repro.data.partition import partition_iid
    from repro.models import get_model
    cfg = get_arch("lenet-radar").reduced.replace(input_hw=(16, 16))
    model = get_model(cfg)
    ds = make_dataset(k * per_node, hw=(16, 16), day=1, seed=0)
    shards = partition_iid(ds, k)
    params0 = model.init(jax.random.PRNGKey(0))
    return model.loss, params0, shards


SIZES = {"linear32": _linear_world, "lenet16": _lenet_world}


def measure(size: str, num_shards: int, rounds: int, k: int = 8,
            local_steps: int = 2, minibatch: int = 4,
            verify_rounds: int = 8) -> Dict:
    """Time host/scan/shard engines; prove shard≡scan bitwise first."""
    loss_fn, params0, shards = SIZES[size](k)
    fed = FedConfig(
        num_nodes=k, local_steps=local_steps, eta=1e-3, zeta=0.3, burn_in=0,
        compressor="topk", compress_ratio=0.1, topology="ring",
        algorithm="cdbfl",
    )
    topo = build_topology(resolve_topology(fed), k)
    comp = make_compressor(fed)
    dshards = DeviceShards.from_shards(shards)
    bank_cfg = DeviceSampleBank(burn_in=0, capacity=16, thin=1)
    key = jax.random.PRNGKey(0)

    from repro.launch.mesh import make_fed_mesh
    mesh = make_fed_mesh(num_shards)

    def build(name):
        shard_ctx = (ShardContext("fed", num_shards) if name == "shard"
                     else None)
        rf = make_round_fn("cdbfl", loss_fn, fed, topo.omega, comp,
                           data_scale=50.0, shard_ctx=shard_ctx)
        return make_engine(name, rf, dshards, local_steps, minibatch,
                           bank=bank_cfg, chunk=16,
                           mesh=mesh if name == "shard" else None)

    def run_engine(name, eng, n, t0=0, state_key=None):
        state = init_fed_state(params0, fed, key=key)
        bs = (eng.make_bank() if name == "host"
              else bank_cfg.init(state.params))
        out = eng.run(state, state_key or jax.random.PRNGKey(1), bs, n, t0=t0)
        return out

    engines = {name: build(name) for name in ("host", "scan", "shard")}

    # -- equivalence proof: shard vs scan (same scan-fused streams) --------
    s_sc = run_engine("scan", engines["scan"], verify_rounds)
    s_sh = run_engine("shard", engines["shard"], verify_rounds)
    bitwise = True
    for a, b in zip(jax.tree.leaves(s_sc[0].params),
                    jax.tree.leaves(s_sh[0].params)):
        a, b = np.asarray(a), np.asarray(b)
        bitwise = bitwise and np.array_equal(a, b)
        if np.abs(a - b).max() > 1e-6:
            raise AssertionError(
                f"shard engine diverged from scan on {size} "
                f"(maxdiff {np.abs(a - b).max()})")
    s_h = run_engine("host", engines["host"], verify_rounds)
    equiv = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                for a, b in zip(jax.tree.leaves(s_h[0].params),
                                jax.tree.leaves(s_sh[0].params)))
    assert equiv < 1e-5, f"shard vs host mismatch on {size}: {equiv}"

    # -- timing ------------------------------------------------------------
    rps = {}
    for name, eng in engines.items():
        state = init_fed_state(params0, fed, key=key)
        bs = (eng.make_bank() if name == "host"
              else bank_cfg.init(state.params))
        state, k2, bs, _, _ = eng.run(state, jax.random.PRNGKey(1), bs,
                                      16)                 # warmup / compile
        t0 = time.perf_counter()
        state, k2, bs, _, _ = eng.run(state, k2, bs, rounds, t0=16)
        jax.block_until_ready(state.params)
        rps[name] = rounds / (time.perf_counter() - t0)

    sh = engines["shard"]
    wire = sh.last_wire_history[-1]
    cross = sh.last_cross_history[-1]
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(params0))
    # per-node f32 row footprint × intra rows (static, from the mix stats)
    from repro.core.gossip import make_shard_mixer
    _, stats = make_shard_mixer(topo.omega, ShardContext("fed", num_shards),
                                config=resolve_topology(fed))
    intra = stats.intra_rows * n_params * 4
    return {
        "size": size, "shards": num_shards, "nodes": k, "rounds": rounds,
        "local_steps": local_steps, "minibatch": minibatch,
        "host_rounds_per_s": rps["host"],
        "scan_rounds_per_s": rps["scan"],
        "shard_rounds_per_s": rps["shard"],
        "shard_vs_scan": rps["shard"] / rps["scan"],
        "wire_bytes_per_node": wire,
        "cross_bytes_per_node": cross,
        "intra_bytes_per_node": intra,
        "equiv_max_abs_diff_vs_host": equiv,
        "bitwise_vs_scan": bitwise,
    }


def _row(rec: Dict) -> str:
    us = 1e6 / rec["shard_rounds_per_s"]
    return (f"shard_engine_{rec['size']}_s{rec['shards']},{us:.0f},"
            f"shard_rps={rec['shard_rounds_per_s']:.1f};"
            f"scan_rps={rec['scan_rounds_per_s']:.1f};"
            f"host_rps={rec['host_rounds_per_s']:.1f};"
            f"wire_B={rec['wire_bytes_per_node']:.0f};"
            f"cross_B={rec['cross_bytes_per_node']:.0f};"
            f"intra_B={rec['intra_bytes_per_node']:.0f}")


def _save(rec: Dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{rec['size']}_s{rec['shards']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def run(quick: bool = False, tiny: bool = False) -> List[str]:
    """Benchmark-suite entry point (CSV rows for benchmarks.run)."""
    ndev = len(jax.devices())
    shard_counts = [s for s in (2, 4, 8) if s <= ndev]
    if not shard_counts:
        return ["shard_engine_SKIPPED,0,needs >=2 devices "
                "(XLA_FLAGS=--xla_force_host_platform_device_count=8)"]
    if tiny:
        plan = [("linear32", s, 32) for s in shard_counts[-1:]]
    elif quick:
        plan = [("linear32", s, 64) for s in shard_counts]
    else:
        plan = [(size, s, 64 if size != "linear32" else 128)
                for size in SIZES for s in shard_counts]
    rows = []
    for size, s, rounds in plan:
        rec = measure(size, s, rounds)
        _save(rec)
        rows.append(_row(rec))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one config on the largest mesh")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, tiny=args.tiny):
        print(row)


if __name__ == "__main__":
    main()
