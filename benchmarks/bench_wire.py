"""Wire accounting: measured payload bytes vs the formula table, plus
pack/unpack throughput of the codec layer (DESIGN.md §2).

For each codec pipeline this reports, over the radar LeNet parameter tree
(the paper's model at CI scale):

* ``measured`` — :meth:`WirePayload.measured_bytes`, summed over the
  actual packed buffers (values + uint16 block-local indices + scales +
  rand-k keys);
* ``formula`` — the closed-form byte table kept as the cross-check;
* the measured/formula ratio (1.0 for sparse codecs up to index-width
  rounding; ~8/bits for the quantizers, whose sub-byte grids materialize
  byte-aligned);
* the paper's headline saving vs a dense fp32 exchange.

Throughput rows time encode/decode of the pipelines and the Pallas
pack/unpack kernels against the dense masked operator. CAVEAT (same as
bench_kernels): Pallas runs interpret=True on CPU, so kernel wall time is
NOT TPU performance — rows exist to track relative regressions.

    PYTHONPATH=src python benchmarks/bench_wire.py [--tiny|--quick]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.config import get_arch
from repro.core.compression import Compressor, parse_pipeline
from repro.kernels import ops
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "wire")

PIPELINES = [
    "topk", "block_topk", "randk", "qsgd", "sign",
    "block_topk|qsgd", "block_topk|sign", "randk|qsgd",
]


def _param_tree(tiny: bool):
    cfg = get_arch("lenet-radar").reduced
    model = get_model(cfg)
    tree = model.init(KEY)
    if tiny:
        tree = jax.tree.map(lambda x: x[..., :1] if x.ndim > 1 else x, tree)
    return tree


def _accounting_rows(tree, ratio: float, save: bool,
                     results_dir: str = None) -> List[str]:
    dense = 4 * sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
    rows = []
    for spec in PIPELINES:
        pipe = parse_pipeline(spec, ratio=ratio, block_size=1024)
        payload = pipe.encode(tree, KEY)
        measured = payload.measured_bytes()
        formula = pipe.formula_bytes(tree)
        # round-trip sanity: the payload decodes to the dense masked tensor
        out = pipe.decode(payload)
        assert all(a.shape == b.shape for a, b in
                   zip(jax.tree.leaves(tree), jax.tree.leaves(out)))
        rec = {
            "pipeline": spec, "ratio": ratio,
            "measured_bytes": measured, "formula_bytes": formula,
            "measured_over_formula": measured / max(formula, 1),
            "dense_bytes": dense,
            "saving_pct": 100.0 * (1 - measured / dense),
            "delta": pipe.delta_for(tree),
        }
        if save:
            results_dir = results_dir or RESULTS_DIR
            os.makedirs(results_dir, exist_ok=True)
            fn = spec.replace("|", "_")
            with open(os.path.join(results_dir, f"{fn}.json"), "w") as f:
                json.dump(rec, f, indent=1)
        rows.append(
            f"wire_{spec.replace('|', '_')},0,"
            f"measured={measured};formula={formula};"
            f"m_over_f={rec['measured_over_formula']:.3f};"
            f"saving={rec['saving_pct']:.2f}%;delta={rec['delta']:.4g}")
    return rows


def _throughput_rows(n: int) -> List[str]:
    x = jax.random.normal(KEY, (n,))
    tree = {"w": x}
    rows = []
    # dense masked operator (the compute-path baseline)
    dense_op = Compressor(name="block_topk", ratio=0.01, block_size=1024)
    t = timeit(lambda: dense_op(tree, KEY), iters=3)
    rows.append(f"wire_dense_masked_op,{t:.0f},n={n}")
    # pipeline encode+decode (jnp path)
    pipe = parse_pipeline("block_topk", ratio=0.01, block_size=1024)
    enc = jax.jit(pipe.encode)
    payload = enc(tree, KEY)
    t = timeit(lambda: enc(tree, KEY), iters=3)
    rows.append(f"wire_encode_jnp,{t:.0f},n={n}")
    dec = jax.jit(pipe.decode)
    t = timeit(lambda: dec(payload), iters=3)
    rows.append(f"wire_decode_jnp,{t:.0f},n={n}")
    # Pallas pack/unpack kernels (interpret=True on CPU)
    t = timeit(lambda: ops.block_topk_pack(x, ratio=0.01, block_size=1024),
               iters=3)
    rows.append(f"wire_pack_pallas_interp,{t:.0f},n={n}")
    vals, idx = ops.block_topk_pack(x, ratio=0.01, block_size=1024)
    t = timeit(lambda: ops.block_topk_unpack(vals, idx, n, (n,),
                                             block_size=1024), iters=3)
    rows.append(f"wire_unpack_pallas_interp,{t:.0f},n={n}")
    return rows


def run(quick: bool = False, tiny: bool = False) -> List[str]:
    """Benchmark-suite entry point (CSV rows for benchmarks.run).

    ``--tiny`` saves its (machine-independent) accounting records under
    ``results/wire_tiny/`` — the byte half of the CI regression gate
    (``benchmarks/check_regression.py``) — keeping the full-tree records
    under ``results/wire/`` untouched.
    """
    tree = _param_tree(tiny)
    tiny_dir = os.path.join(os.path.dirname(__file__), "results",
                            "wire_tiny")
    rows = _accounting_rows(tree, ratio=0.01, save=True,
                            results_dir=tiny_dir if tiny else None)
    if tiny:
        rows += _throughput_rows(2 ** 14)
    else:
        rows += _throughput_rows(2 ** 18 if quick else 2 ** 21)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: trimmed tree + small leaves, ~seconds")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, tiny=args.tiny):
        print(row)


if __name__ == "__main__":
    main()
