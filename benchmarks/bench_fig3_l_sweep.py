"""Paper Fig. 3: CD-BFL accuracy/ECE vs local steps L, against DSGLD.

Claim: accuracy and ECE improve with L up to a sweet spot (paper: L=8),
then degrade (overfitting in the local phase hurts calibration); CD-BFL at
the sweet spot ≈ DSGLD accuracy at 1% of the bytes.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import ROUNDS, radar_world, run_method


def run(quick: bool = False) -> List[str]:
    rows = []
    cfg, model, shards, test_d1, _ = radar_world()
    rounds = 60 if quick else ROUNDS
    l_values = [1, 4, 8] if quick else [1, 2, 4, 8, 12]

    _, res_d = run_method(model, shards, "dsgld", rounds=rounds,
                          eval_batch=test_d1)
    rows.append(f"fig3_dsgld,{res_d.wall_s*1e6/rounds:.0f},"
                f"acc={res_d.accuracy:.4f};ece={res_d.ece:.4f};"
                f"bytes_per_round={res_d.bytes_sent_per_round:.3e}")

    for L in l_values:
        _, res = run_method(model, shards, "cdbfl", local_steps=L,
                            rounds=rounds, eval_batch=test_d1)
        rows.append(f"fig3_cdbfl_L{L},{res.wall_s*1e6/rounds:.0f},"
                    f"acc={res.accuracy:.4f};ece={res.ece:.4f};"
                    f"bytes_per_round={res.bytes_sent_per_round:.3e}")
    return rows
