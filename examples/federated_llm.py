"""Beyond the paper: CD-BFL pre-training of an assigned LLM architecture.

Each federated node holds a *distribution-skewed* token stream (distinct
Markov transition structure) — the cross-pod deployment of DESIGN.md §2 at
CPU scale. Compares CD-BFL against uncompressed DSGLD on perplexity and
bytes moved, demonstrating that the paper's 99% communication cut carries
over from 2.7M-param radar CNNs to transformer LMs.

    PYTHONPATH=src python examples/federated_llm.py --arch smollm-135m
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig, get_arch
from repro.core import (init_fed_state, make_compressor, make_round_fn,
                        mixing_matrix)
from repro.data.synthetic_lm import fed_lm_round_batch, markov_tokens
from repro.models import get_model


def run(algorithm: str, args, cfg, model):
    # data_scale = per-node corpus size: sharpens the likelihood so the
    # posterior concentrates (data_scale=1 would leave the N(0,I) prior
    # dominant — correct Bayes, useless LM). temperature<1 = cold posterior.
    fed = FedConfig(num_nodes=args.nodes, local_steps=args.local_steps,
                    eta=args.eta, zeta=0.3, topology="ring",
                    compressor="block_topk", compress_ratio=0.01,
                    fused_compress=args.fused,
                    temperature=args.temperature, algorithm=algorithm)
    omega = mixing_matrix(fed.topology, fed.num_nodes)
    comp = make_compressor(fed)
    round_fn = jax.jit(make_round_fn(algorithm, model.loss, fed, omega, comp,
                                     data_scale=args.data_scale))
    key = jax.random.PRNGKey(0)
    state = init_fed_state(model.init(key), fed, key=key)
    wire = (comp.wire_bytes(model.init(key)) if algorithm != "dsgld"
            else 4 * sum(int(np.prod(x.shape))
                         for x in jax.tree.leaves(model.init(key))))
    losses = []
    t0 = time.time()
    for t in range(args.rounds):
        batch = fed_lm_round_batch(fed.num_nodes, fed.local_steps,
                                   args.batch, args.seq, cfg.vocab_size,
                                   seed=t)
        state, m = round_fn(state, jax.tree.map(jnp.asarray, batch),
                            jax.random.fold_in(key, t))
        losses.append(float(m.loss.mean()))
    # held-out per-node eval
    eval_nll = []
    for node in range(fed.num_nodes):
        toks = jnp.asarray(markov_tokens(args.batch, args.seq,
                                         cfg.vocab_size, seed=10_000,
                                         node=node))
        params_k = jax.tree.map(lambda x: x[node], state.params)
        nll, _ = model.loss(params_k, {"tokens": toks})
        eval_nll.append(float(nll))
    return {
        "loss0": losses[0], "lossT": losses[-1],
        "ppl": float(np.exp(np.mean(eval_nll))),
        "bytes_round": wire, "s_round": (time.time() - t0) / args.rounds,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--eta", type=float, default=2e-5)
    ap.add_argument("--data-scale", type=float, default=500.0)
    ap.add_argument("--temperature", type=float, default=0.1)
    ap.add_argument("--fused", action="store_true",
                    help="fused compress-in-update (DESIGN.md §13): encode "
                         "Q(θ−v) straight from (θ, v) in Pallas; bitwise-"
                         "identical trajectory, ~3x less encode HBM traffic")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced
    model = get_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params0))
    print(f"== federated LM pretraining: {cfg.name} ({n/1e6:.2f}M params, "
          f"K={args.nodes} skewed nodes"
          f"{', fused compress' if args.fused else ''}) ==")
    if args.fused:
        # roofline usefulness of the fused encode: HBM bytes actually
        # moved over the 2p-reads + wire-writes floor (1.0 = optimal)
        from repro.core.compression import encode_hbm_bytes
        comp = make_compressor(FedConfig(compressor="block_topk",
                                         fused_compress=True))
        ledger = encode_hbm_bytes(comp, params0, params0)
        two_pass = encode_hbm_bytes(dataclasses.replace(comp, fused=False),
                                    params0, params0)
        print(f"fused encode: {ledger['hbm_bytes']:.3e} HBM B/node/round "
              f"({ledger['hbm_bytes'] / ledger['lower_bound_bytes']:.2f}x "
              f"of the 2p+wire bound; two-pass "
              f"{two_pass['hbm_bytes'] / ledger['hbm_bytes']:.2f}x more)")

    for algo in ("cdbfl", "dsgld"):
        r = run(algo, args, cfg, model)
        print(f"{algo:6s} loss {r['loss0']:.3f}->{r['lossT']:.3f} "
              f"ppl={r['ppl']:.1f} bytes/round={r['bytes_round']:.3e} "
              f"({r['s_round']:.2f}s/round)")
    print("CD-BFL reaches comparable loss at ~1% of DSGLD's bytes.")


if __name__ == "__main__":
    main()
