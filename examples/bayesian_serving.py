"""Uncertainty-aware serving: posterior-sample (BMA) batched decoding.

Wraps repro.launch.serve: decodes with multiple posterior samples and shows
the predictive-entropy safety signal — high entropy -> abstain/escalate,
the serving-side counterpart of the paper's calibration claim.

    PYTHONPATH=src python examples/bayesian_serving.py --arch qwen2.5-14b
"""
import argparse
import sys

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    args, _ = ap.parse_known_args()
    sys.argv = [sys.argv[0], "--arch", args.arch, "--trim", "--batch", "4",
                "--steps", "16", "--samples", "3"]
    serve.main()


if __name__ == "__main__":
    main()
