"""Uncertainty-aware serving: continuous-batching BMA over a posterior bank.

Drives the ``repro.serve`` engine API directly (the CLI equivalent is
``python -m repro.launch.serve``): builds a small posterior bank, submits
requests into the slot table while earlier ones are still decoding, and
reads the predictive-entropy safety signal off each response — high
entropy -> ``abstain=True`` -> route to a human, the serving-side
counterpart of the paper's calibration claim (DESIGN.md §14).

    PYTHONPATH=src python examples/bayesian_serving.py                # decode
    PYTHONPATH=src python examples/bayesian_serving.py --mode classify
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, get_arch
from repro.models import get_model
from repro.serve import ClassifyEngine, DecodeEngine, ServeRequest


def synthetic_bank(model, samples, key):
    """Jittered inits standing in for an SGLD chain (see launch.train
    --bank-capacity for the real train -> snapshot -> serve pipeline)."""
    ps = [model.init(jax.random.fold_in(key, i)) for i in range(samples)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="decode",
                    choices=["decode", "classify"])
    ap.add_argument("--arch", default=None,
                    help="default: smollm-135m (decode) / lenet-radar")
    ap.add_argument("--samples", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    arch = args.arch or ("lenet-radar" if args.mode == "classify"
                         else "smollm-135m")
    cfg = get_arch(arch).reduced
    model = get_model(cfg)
    stacked = synthetic_bank(model, args.samples, jax.random.PRNGKey(0))

    if args.mode == "classify":
        from repro.data.radar import make_dataset
        ds = make_dataset(args.requests, hw=cfg.input_hw, seed=7)
        scfg = ServeConfig(slots=4, entropy_threshold=1.2)
        eng = ClassifyEngine(lambda p, b: model.logits(p, b), scfg,
                             input_shape=ds["x"].shape[1:], stacked=stacked)
        reqs = [ServeRequest(x=ds["x"][i]) for i in range(args.requests)]
    else:
        scfg = ServeConfig(slots=4, max_len=32, max_new_tokens=8,
                           entropy_threshold=0.8 * np.log(cfg.vocab_size))
        eng = DecodeEngine(model, scfg, stacked=stacked)
        reqs = [ServeRequest(prompt_token=1 + i % (cfg.vocab_size - 1),
                             seed=i)
                for i in range(args.requests)]

    # continuous batching: submit everything, drain step by step — the
    # engine admits/retires per decode step against the fixed slot table
    for r in reqs:
        eng.submit(r)
    resps = []
    while eng.pending():
        resps.extend(eng.step())
    resps.sort(key=lambda r: r.request_id)

    for r in resps:
        verdict = "ABSTAIN -> human" if r.abstain else "serve"
        tail = (f" tokens={r.tokens.tolist()}" if r.tokens is not None
                else f" pred={int(np.argmax(r.probs))}")
        print(f"req {r.request_id:2d}: entropy={r.entropy:.3f} nats "
              f"[{verdict}] latency_ms={1e3 * r.latency_s:.1f}{tail}")
    st = eng.stats()
    print(f"\nserved={int(st['served'])} "
          f"abstain_rate={st['abstain_rate']:.2f} "
          f"p50_ms={st['p50_ms']:.1f} p99_ms={st['p99_ms']:.1f} "
          f"(bank S={eng.num_samples()}, {eng.compile_count()} compiles "
          f"for {int(st['steps'])} steps)")


if __name__ == "__main__":
    main()
