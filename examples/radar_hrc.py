"""End-to-end driver: the paper's IIoT case study (§IV-V).

K radar devices in a human-robot-collaboration workspace collaboratively
train a LeNet ROI classifier with CD-BFL, then evaluate accuracy + ECE with
Bayesian model averaging — including the distribution-shift test (days 2-3,
safety-critical labels 1-6) that motivates Bayesian FL.

Reduced scale by default (CPU container); pass --paper-scale on real
hardware for the 256×63 / T=800 / K=10 configuration.

    PYTHONPATH=src python examples/radar_hrc.py --rounds 150
"""
import argparse

import numpy as np

from repro.config import FedConfig, get_arch
from repro.core import calibration as cal
from repro.data.partition import partition_iid
from repro.data.radar import critical_subset, make_dataset
from repro.models import get_model
from repro.train import FedTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--nodes", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--algorithm", default="cdbfl",
                    choices=["cdbfl", "dsgld", "cffl"])
    args = ap.parse_args()

    spec = get_arch("lenet-radar")
    cfg = spec.config if args.paper_scale else spec.reduced
    K = 10 if args.paper_scale else args.nodes
    model = get_model(cfg)

    print(f"== CD-BFL radar HRC workspace ({cfg.name}, K={K}) ==")
    train = make_dataset(K * 50, hw=cfg.input_hw, day=1, seed=0)
    shards = partition_iid(train, K)
    test_d1 = make_dataset(300, hw=cfg.input_hw, day=1, seed=99)
    shift = {
        k: np.concatenate([
            critical_subset(make_dataset(250, hw=cfg.input_hw, day=d,
                                         seed=90 + d))[k] for d in (2, 3)])
        for k in ("x", "y")
    }

    fed = FedConfig(
        num_nodes=K, local_steps=args.local_steps,
        eta=1e-4 if args.paper_scale else 3e-3,
        zeta=0.03 if args.paper_scale else 0.3,
        # cold posterior at reduced scale (see EXPERIMENTS §Repro); T=1 at
        # the paper's own 2.7M-param scale
        temperature=1.0 if args.paper_scale else 0.2,
        rounds=args.rounds, burn_in=int(args.rounds * 0.66),
        compressor="block_topk", compress_ratio=0.01, topology="full",
        algorithm=args.algorithm,
    )
    trainer = FedTrainer(model, fed, shards, minibatch=10)
    print(f"wire bytes/node/round: {trainer.compressor.wire_bytes(trainer.state.params)/fed.num_nodes/1e3:.1f} kB "
          f"(dense would be "
          f"{4 * sum(np.prod(x.shape) for x in __import__('jax').tree.leaves(trainer.state.params)) / fed.num_nodes / 1e3:.0f} kB)")

    res = trainer.run(rounds=args.rounds, log_every=max(args.rounds // 5, 1),
                      eval_batch=test_d1)
    print(f"\nday-1 test:   acc={res.accuracy:.3f} ece={res.ece:.3f} "
          f"nll={res.nll:.3f}")

    res_s = trainer.evaluate(shift)
    print(f"days-2/3 (critical labels 1-6): acc={res_s.accuracy:.3f} "
          f"ece={res_s.ece:.3f}")
    import jax.numpy as jnp
    bins = cal.reliability_bins(jnp.asarray(res_s.probs),
                                jnp.asarray(res_s.labels))
    print(cal.render_reliability(bins, f"{args.algorithm} under shift"))
    print(f"\ntotal communication: {res.total_bytes/1e6:.1f} MB over "
          f"{args.rounds} rounds")


if __name__ == "__main__":
    main()
