"""Continual-training demo: drift mid-run, posterior aging, node unlearning.

The factory floor does not hold its distribution still (DESIGN.md §15):
this driver trains CD-BFL while the training distribution itself shifts
to the day-2/3 critical cell at ``--onset``, with the sample bank kept
current by a moving window + age-decayed BMA weights. Probe evals show
shifted-test ECE spike at onset and come back. Afterwards one node is
deleted from the posterior with ``FedTrainer.unlearn`` and the
predictive views are re-scored without it.

Reduced scale by default (CPU container, ~1 min):

    PYTHONPATH=src python examples/drift_unlearn.py
    PYTHONPATH=src python examples/drift_unlearn.py --rounds 90 --onset 45
"""
import argparse

from repro.config import ContinualConfig, FedConfig, get_arch
from repro.data.partition import partition_iid
from repro.data.radar import make_dataset
from repro.data.scenarios import make_scenario_dataset
from repro.models import get_model
from repro.train import FedTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--onset", type=int, default=16,
                    help="first drifted round (step schedule)")
    ap.add_argument("--scenario", default="day23_critical")
    ap.add_argument("--severity", type=float, default=1.0)
    ap.add_argument("--window", type=int, default=12,
                    help="bank aging window in rounds (0 = keep all)")
    ap.add_argument("--decay", type=float, default=0.9,
                    help="per-round BMA weight decay")
    ap.add_argument("--unlearn", type=int, default=None,
                    help="node id to delete after training "
                         "(default: last node)")
    args = ap.parse_args()

    cfg = get_arch("lenet-radar").reduced
    model = get_model(cfg)
    K = args.nodes

    train = make_dataset(K * 32, hw=cfg.input_hw, day=1, seed=0)
    shards = partition_iid(train, K)
    # probe on the *drifted* distribution: this is the ECE that spikes
    shifted_test = make_scenario_dataset(
        args.scenario, args.severity, 160, hw=cfg.input_hw, seed=77)

    fed = FedConfig(
        num_nodes=K, local_steps=4, eta=3e-3, zeta=0.3, temperature=0.2,
        rounds=args.rounds, burn_in=max(args.rounds // 6, 2),
        compressor="block_topk", compress_ratio=0.05, topology="full",
        algorithm="cdbfl",
        continual=ContinualConfig(
            scenario=args.scenario, schedule="step",
            severity=args.severity, onset=args.onset,
            refresh_every=4, window=args.window, decay=args.decay),
    )
    tr = FedTrainer(model, fed, shards, minibatch=8, bank_capacity=16,
                    bank_thin=1)

    print(f"== drift demo: {args.scenario}@{args.severity} switches on at "
          f"round {args.onset}/{args.rounds}; bank window {args.window}, "
          f"decay {args.decay} ==")
    probe_every = max(args.rounds // 10, 2)
    res = tr.run(rounds=args.rounds, eval_batch=shifted_test,
                 eval_every=probe_every)
    print(f"\n  {'round':>5}  {'sev':>4}  {'acc':>6}  {'ece':>6}")
    sched = tr._refresher.schedule if tr._refresher is not None else None
    for snap in res.eval_history:
        t = int(snap["round"])
        sev = sched.severity_at(t) if sched is not None else 0.0
        print(f"  {t:>5}  {sev:>4.2f}  {snap['accuracy']:>6.3f}  "
              f"{snap['ece']:>6.3f}")
    final = tr.eval_report(shifted_test)
    print(f"\nfinal (aged BMA over {len(tr.bank)} bank samples): "
          f"acc={final.accuracy:.3f} ece={final.ece:.3f}")

    # -- unlearning --------------------------------------------------------
    target = args.unlearn if args.unlearn is not None else K - 1
    tr.unlearn(target)
    after = tr.eval_report(shifted_test)
    print(f"after unlearn(node {target}):          "
          f"acc={after.accuracy:.3f} ece={after.ece:.3f} "
          f"(removed {sorted(tr.unlearned)}; remaining chains "
          f"{K - len(tr.unlearned)})")
    print("exact-removal contract: bank rows + gossip control variates "
          "zeroed;\nresidual gossip influence bounded by the retrain "
          "oracle (tests/test_unlearn.py)")


if __name__ == "__main__":
    main()
