"""Quickstart: CD-BFL in ~60 lines on a toy Bayesian linear regression.

Shows the public API end to end: compression operator, mixing matrix,
federated state, one-call round function, posterior collection, and the
communication-savings accounting.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig
from repro.core import (init_fed_state, make_compressor, make_cdbfl_round,
                        mixing_matrix)

# --- problem: K nodes observe y = x·w* + noise ---------------------------
K, DIM, L = 8, 16, 4
key = jax.random.PRNGKey(0)
w_true = jax.random.normal(key, (DIM,))
X = jax.random.normal(jax.random.fold_in(key, 1), (K, L, 32, DIM))
Y = X @ w_true + 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                         (K, L, 32))


def loss_fn(params, batch, key):
    x, y = batch
    return 0.5 * jnp.mean((x @ params["w"] - y) ** 2) * 100, ()


# --- CD-BFL (paper Algorithm 1) -------------------------------------------
fed = FedConfig(num_nodes=K, local_steps=L, eta=2e-3, zeta=0.3,
                topology="ring", compressor="block_topk",
                compress_ratio=0.05, burn_in=150)
omega = mixing_matrix(fed.topology, K)
compressor = make_compressor(fed)
round_fn = jax.jit(make_cdbfl_round(loss_fn, fed, omega, compressor))

state = init_fed_state({"w": jnp.zeros((DIM,))}, fed)
posterior = []
for t in range(400):
    state, metrics = round_fn(state, (X, Y), jax.random.fold_in(key, t))
    if t >= fed.burn_in and t % 5 == 0:
        posterior.append(np.asarray(state.params["w"]))
    if (t + 1) % 100 == 0:
        print(f"round {t+1:3d} loss={float(metrics.loss.mean()):8.4f} "
              f"consensus={float(metrics.consensus_error):.2e}")

# --- posterior summary -----------------------------------------------------
samples = np.concatenate(posterior, axis=0)          # (S*K, DIM)
w_hat, w_std = samples.mean(0), samples.std(0)
err = np.linalg.norm(w_hat - np.asarray(w_true)) / np.linalg.norm(w_true)
print(f"\nposterior mean rel-err: {err:.4f}")
print(f"posterior std (uncertainty): mean {w_std.mean():.4f}")

dense = 4 * DIM * K * (K - 1)
wire = compressor.wire_bytes({"w": jnp.zeros((DIM,))}) * K * (K - 1)
print(f"bytes/round: dense {dense} vs compressed {wire} "
      f"({100 * (1 - wire / dense):.0f}% saved)")
assert err < 0.1, "quickstart should recover w*"
print("OK")
