"""Docs lint: code fences and cross-references must match the live code.

Three classes of rot this catches, all of which have bitten by-hand docs:

  1. CLI drift — a fence shows ``python -m repro.launch.train --foo`` but
     the parser never grew ``--foo`` (or it was renamed).  Flags used in
     fenced commands are checked against the ``add_argument`` declarations
     of the module actually named on that line.
  2. Registry drift — ``--arch``/``--scenarios``/``--drift`` operands must
     name entries in the live arch / scenario registries.
  3. Dead cross-references — ``§N`` mentions must resolve to a
     ``## §N`` heading in DESIGN.md, ``EXPERIMENTS.md §Name`` mentions to a
     ``## §Name`` heading there, and in-file ``[...](#anchor)`` links to a
     real heading slug.

Needs the repo importable (registries), so it runs in the tier-1 CI job,
not the dependency-free lint job:

    PYTHONPATH=src python tools/docs_lint.py

Exit status is the number of problems found; each is printed one per line
as ``file:line: message``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import Dict, List, Set, Tuple

ROOT = Path(__file__).resolve().parents[1]
DOCS = ["README.md", "EXPERIMENTS.md", "DESIGN.md"]

# flags that argparse provides for free
IMPLICIT_FLAGS = {"--help"}


def _module_source(module: str) -> Path | None:
    """Map a ``python -m`` module path to its source file, if in-repo."""
    if module.startswith("repro."):
        p = ROOT / "src" / (module.replace(".", "/") + ".py")
    elif module.startswith("benchmarks."):
        p = ROOT / (module.replace(".", "/") + ".py")
    else:
        return None  # pytest, pip, ... — not ours to check
    return p if p.exists() else None


def _declared_flags(path: Path) -> Set[str]:
    txt = path.read_text()
    flags = set(re.findall(r"add_argument\(\s*['\"](--[A-Za-z0-9][A-Za-z0-9-]*)", txt))
    return flags | IMPLICIT_FLAGS


def _fences(text: str) -> List[Tuple[int, str]]:
    """Return (start_line, body) for each fenced code block."""
    out = []
    lines = text.split("\n")
    i = 0
    while i < len(lines):
        if lines[i].lstrip().startswith("```"):
            start = i + 1
            j = i + 1
            while j < len(lines) and not lines[j].lstrip().startswith("```"):
                j += 1
            out.append((start + 1, "\n".join(lines[start:j])))  # 1-based
            i = j + 1
        else:
            i += 1
    return out


def _commands(body: str) -> List[str]:
    """Join backslash continuations, keep lines that invoke ``python -m``."""
    joined: List[str] = []
    acc = ""
    for raw in body.split("\n"):
        line = raw.rstrip()
        if acc:
            acc += " " + line.strip().rstrip("\\").strip()
            if not line.endswith("\\"):
                joined.append(acc)
                acc = ""
        elif line.endswith("\\"):
            acc = line.rstrip("\\").strip()
        else:
            joined.append(line)
    if acc:
        joined.append(acc)
    return [ln for ln in joined if "python -m " in ln]


def _inline_commands(text: str) -> List[Tuple[int, str]]:
    """``python -m ...`` invocations inside backtick inline code spans."""
    out = []
    for ln, line in enumerate(text.split("\n"), start=1):
        for span in re.findall(r"`([^`]*python -m [^`]*)`", line):
            out.append((ln, span))
    return out


def _check_command(cmd: str, where: str, problems: List[str],
                   scenarios: Set[str], archs: Set[str]) -> None:
    m = re.search(r"python -m\s+([A-Za-z0-9_.]+)", cmd)
    if not m:
        return
    module = m.group(1)
    src = _module_source(module)
    if src is None:
        if module.startswith(("repro.", "benchmarks.")):
            problems.append(f"{where}: no such module `{module}`")
        return
    declared = _declared_flags(src)
    tail = cmd[m.end():]
    tokens = tail.split()
    used = [t.split("=")[0] for t in tokens if t.startswith("--")]
    for flag in used:
        if flag not in declared:
            problems.append(
                f"{where}: `{module}` has no flag `{flag}` "
                f"(declared: {', '.join(sorted(declared))})")
    # registry-valued operands
    for i, tok in enumerate(tokens[:-1]):
        val = tokens[i + 1]
        if tok == "--arch" and val not in archs:
            problems.append(f"{where}: unknown arch `{val}`")
        elif tok in ("--drift", "--scenario"):
            if val not in scenarios:
                problems.append(f"{where}: unknown scenario `{val}`")
        elif tok == "--scenarios" and val != "all":
            for name in val.split(","):
                if name and name not in scenarios:
                    problems.append(f"{where}: unknown scenario `{name}`")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug."""
    h = heading.strip().lstrip("#").strip().lower()
    h = re.sub(r"[`*]", "", h)
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _headings(text: str) -> List[str]:
    return [ln for ln in text.split("\n") if re.match(r"^#{1,6}\s", ln)]


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    from repro.config import list_archs
    from repro.data.scenarios import list_scenarios

    scenarios = set(list_scenarios()) | {"clean"}
    archs = set(list_archs())

    texts: Dict[str, str] = {d: (ROOT / d).read_text() for d in DOCS}
    design_secs = set(re.findall(r"^## §(\d+)\b", texts["DESIGN.md"], re.M))
    exp_secs = set(re.findall(r"^## §(\w+)", texts["EXPERIMENTS.md"], re.M))

    problems: List[str] = []
    for doc, text in texts.items():
        # 1+2: fenced + inline commands
        for start, body in _fences(text):
            for cmd in _commands(body):
                _check_command(cmd, f"{doc}:{start}", problems, scenarios, archs)
        for ln, cmd in _inline_commands(text):
            _check_command(cmd, f"{doc}:{ln}", problems, scenarios, archs)

        # 3a: §N references must exist in DESIGN.md; EXPERIMENTS.md §Name
        # references must exist there.  A bare §Name outside EXPERIMENTS.md
        # is prose, not a link, and is left alone.
        for ln, line in enumerate(text.split("\n"), start=1):
            for num in re.findall(r"§§?(\d+)", line):
                if num not in design_secs:
                    problems.append(f"{doc}:{ln}: dead section ref §{num} "
                                    f"(DESIGN.md has §1–§{max(map(int, design_secs))})")
            for name in re.findall(r"EXPERIMENTS\.md §(\w+)", line):
                if name not in exp_secs:
                    problems.append(f"{doc}:{ln}: dead ref EXPERIMENTS.md §{name}")

        # 3b: in-file anchors
        slugs = {_slug(h) for h in _headings(text)}
        for ln, line in enumerate(text.split("\n"), start=1):
            for anchor in re.findall(r"\]\(#([^)]+)\)", line):
                if anchor not in slugs:
                    problems.append(f"{doc}:{ln}: dead anchor #{anchor}")

    for p in problems:
        print(p)
    if not problems:
        print(f"docs_lint: {len(DOCS)} docs clean "
              f"({len(scenarios)} scenarios, {len(archs)} archs, "
              f"{len(design_secs)} DESIGN sections)")
    return min(len(problems), 125)


if __name__ == "__main__":
    sys.exit(main())
